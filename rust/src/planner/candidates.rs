//! Candidate enumeration and pruning for the parallelism-plan search.
//!
//! Every feasible TP×PP×DP factorization is crossed with partitioning
//! strategies, ring policies and pipeline schedules. Pruning is typed
//! and two-level: whole factorizations fall to structural reasons
//! (cross-node TP, indivisible layers, batch floor, weights+optimizer
//! memory), individual `(factorization, schedule)` pairs fall when the
//! schedule's peak-activation estimate pushes the smallest device over
//! its memory capacity — the schedule × heterogeneity interaction the
//! paper's homogeneous baselines cannot express.

use crate::config::cluster::ClusterSpec;
use crate::config::framework::ParallelismSpec;
use crate::config::model::ModelSpec;
use crate::system::collective::RingPolicy;
use crate::workload::schedule::ScheduleKind;

/// How the model/batch is split across device groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioning {
    /// Equal layer splits and batch shares (the SimAI assumption).
    Uniform,
    /// Non-uniform splits proportional to device-group compute power
    /// (component C1, [`crate::workload::partition::plan_hetero`]).
    HeteroAware,
}

impl Partitioning {
    /// Stable name used in candidate keys.
    pub fn name(self) -> &'static str {
        match self {
            Partitioning::Uniform => "uniform",
            Partitioning::HeteroAware => "hetero",
        }
    }
}

/// One candidate deployment plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanCandidate {
    /// Parallelism degrees.
    pub par: ParallelismSpec,
    /// How layers/batch are split across device groups.
    pub partitioning: Partitioning,
    /// Collective ring-ordering policy.
    pub ring: RingPolicy,
    /// Pipeline schedule ordering each group's microbatches.
    pub schedule: ScheduleKind,
}

impl PlanCandidate {
    /// Stable human-readable identity; doubles as the deterministic
    /// ranking tie-break.
    pub fn key(&self) -> String {
        format!(
            "tp{}-pp{}-dp{}-{}-{}-{}",
            self.par.tp,
            self.par.pp,
            self.par.dp,
            self.partitioning.name(),
            match self.ring {
                RingPolicy::HeteroAware => "ring:aware",
                RingPolicy::Naive => "ring:naive",
            },
            self.schedule.name(),
        )
    }
}

/// Why a factorization (or one of its schedules) was excluded from the
/// search (typed so reports never truncate silently).
#[derive(Debug, Clone, thiserror::Error)]
pub enum PruneReason {
    /// TP groups may not span node boundaries (NVLink domain).
    #[error("TP degree {tp} exceeds gpus per node {gpn} (cross-node TP)")]
    CrossNodeTp {
        /// Rejected TP degree.
        tp: u32,
        /// GPUs per node of the smallest node.
        gpn: u32,
    },
    /// The uniform mapping needs `layers % pp == 0`.
    #[error("PP degree {pp} does not divide the {layers} model layers")]
    IndivisibleLayers {
        /// Rejected PP degree.
        pp: u32,
        /// Model layer count.
        layers: u32,
    },
    /// Each DP replica needs at least one sample per iteration.
    #[error("DP degree {dp} exceeds the global batch {batch}")]
    BatchTooSmall {
        /// Rejected DP degree.
        dp: u32,
        /// Global batch size.
        batch: u64,
    },
    /// Weights + gradients + optimizer state exceed the smallest device.
    #[error("~{need_gb:.1} GB/GPU exceeds the smallest device memory ({have_gb:.1} GB)")]
    MemoryExceeded {
        /// Estimated bytes per GPU, in GB.
        need_gb: f64,
        /// Smallest device capacity, in GB.
        have_gb: f64,
    },
    /// Weights + the schedule's peak activation residency exceed the
    /// smallest device (schedule-level prune: other schedules of the
    /// same factorization may survive; the schedule is carried by
    /// [`PrunedCandidate::schedule`]).
    #[error(
        "~{need_gb:.1} GB/GPU incl. schedule activations exceeds the smallest \
         device memory ({have_gb:.1} GB)"
    )]
    ActivationMemoryExceeded {
        /// Estimated bytes per GPU (weights + activations), in GB.
        need_gb: f64,
        /// Smallest device capacity, in GB.
        have_gb: f64,
    },
}

/// A factorization (or factorization × schedule) that was excluded, and
/// why.
#[derive(Debug, Clone)]
pub struct PrunedCandidate {
    /// The excluded parallelism degrees.
    pub par: ParallelismSpec,
    /// The specific schedule excluded, when the prune is
    /// schedule-level (`None` = the whole factorization fell).
    pub schedule: Option<ScheduleKind>,
    /// Typed exclusion reason.
    pub reason: PruneReason,
}

/// Coarse per-GPU memory estimate for a (tp, pp) sharding: bf16 weights
/// + fp32 gradients + fp32 Adam moments (8 bytes/param).
pub fn memory_bytes_per_gpu(model: &ModelSpec, tp: u32, pp: u32) -> u64 {
    let per_param = model.dtype_bytes + model.grad_dtype_bytes + 8;
    model.params_per_gpu(tp, pp) * per_param
}

/// Pipeline schedules worth exploring for a factorization: GPipe
/// always; 1F1B and interleaved (vpp = 2) once there is a real pipeline
/// (and, for interleaved, at least 2 layers per stage to chunk).
pub fn schedules_for(model: &ModelSpec, pp: u32) -> Vec<ScheduleKind> {
    let mut s = vec![ScheduleKind::GPipe];
    if pp > 1 {
        s.push(ScheduleKind::OneFOneB);
        if model.num_layers / pp >= 2 {
            s.push(ScheduleKind::Interleaved1F1B { vpp: 2 });
        }
    }
    s
}

/// Enumerate every valid TP×PP×DP factorization of the cluster's world
/// size, crossed with partitioning strategies, ring policies and
/// pipeline schedules. Returns `(feasible candidates, pruned
/// factorizations)`. On homogeneous clusters the heterogeneity-aware
/// partitioning reduces to the uniform mapping and is skipped to avoid
/// duplicate work; on `pp == 1` factorizations the schedules collapse
/// to GPipe for the same reason.
///
/// `microbatch_limit` mirrors the evaluation's
/// [`crate::workload::aicb::WorkloadOptions::microbatch_limit`]: the
/// schedule peak-activation estimate is computed for the microbatch
/// count that will actually be simulated (`None` = the full batch, the
/// honest deployment-feasibility check).
pub fn enumerate(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    microbatch_limit: Option<u64>,
) -> (Vec<PlanCandidate>, Vec<PrunedCandidate>) {
    let world = cluster.total_gpus();
    // smallest node bounds intra-node TP (defensive: validated clusters
    // have uniform gpus_per_node, but don't trust only the first node)
    let gpn = cluster.nodes.iter().map(|n| n.gpus_per_node).min().unwrap_or(0);
    let min_mem = cluster.nodes.iter().map(|n| n.gpu.mem_capacity).min().unwrap_or(0);
    let hetero = !cluster.is_homogeneous();
    let mut keep = Vec::new();
    let mut pruned = Vec::new();
    for tp in 1..=world {
        if world % tp != 0 {
            continue;
        }
        for pp in 1..=(world / tp) {
            if (world / tp) % pp != 0 {
                continue;
            }
            let dp = world / tp / pp;
            let par = ParallelismSpec { tp, pp, dp };
            let weights = memory_bytes_per_gpu(model, tp, pp);
            let reason = if tp > gpn {
                Some(PruneReason::CrossNodeTp { tp, gpn })
            } else if model.num_layers % pp != 0 {
                Some(PruneReason::IndivisibleLayers { pp, layers: model.num_layers })
            } else if u64::from(dp) > model.global_batch {
                Some(PruneReason::BatchTooSmall { dp, batch: model.global_batch })
            } else if weights > min_mem {
                Some(PruneReason::MemoryExceeded {
                    need_gb: weights as f64 / 1e9,
                    have_gb: min_mem as f64 / 1e9,
                })
            } else {
                None
            };
            if let Some(reason) = reason {
                pruned.push(PrunedCandidate { par, schedule: None, reason });
                continue;
            }
            // microbatches one device group will actually simulate
            // (uniform-split approximation for the estimate)
            let m_full = (model.global_batch / (u64::from(dp) * model.micro_batch)).max(1);
            let m_eff = microbatch_limit.map_or(m_full, |l| m_full.min(l.max(1)));
            let partitionings: &[Partitioning] = if hetero {
                &[Partitioning::Uniform, Partitioning::HeteroAware]
            } else {
                &[Partitioning::Uniform]
            };
            for schedule in schedules_for(model, pp) {
                // schedule-level memory prune: weights + peak activations
                let need = weights + schedule.peak_activation_bytes(model, tp, pp, m_eff);
                if need > min_mem {
                    pruned.push(PrunedCandidate {
                        par,
                        schedule: Some(schedule),
                        reason: PruneReason::ActivationMemoryExceeded {
                            need_gb: need as f64 / 1e9,
                            have_gb: min_mem as f64 / 1e9,
                        },
                    });
                    continue;
                }
                for &partitioning in partitionings {
                    for ring in [RingPolicy::HeteroAware, RingPolicy::Naive] {
                        keep.push(PlanCandidate { par, partitioning, ring, schedule });
                    }
                }
            }
        }
    }
    (keep, pruned)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn hetero_preset_yields_enough_candidates() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, pruned) = enumerate(&m, &c, Some(2));
        // acceptance floor for `hetsim plan` on this pair
        assert!(keep.len() >= 8, "only {} candidates", keep.len());
        assert!(!pruned.is_empty());
        // every feasible factorization divides the world
        for cand in &keep {
            assert_eq!(cand.par.world_size(), c.total_gpus());
        }
        // the uniform default plan is in the candidate set
        let def = crate::simulator::infer_parallelism(&m, &c).unwrap();
        assert!(keep.iter().any(|cand| {
            cand.par == def
                && cand.partitioning == Partitioning::Uniform
                && cand.ring == RingPolicy::HeteroAware
                && cand.schedule == ScheduleKind::GPipe
        }));
    }

    #[test]
    fn all_three_schedule_kinds_enumerated() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, _) = enumerate(&m, &c, Some(2));
        assert!(keep.iter().any(|cand| cand.schedule == ScheduleKind::GPipe));
        assert!(keep.iter().any(|cand| cand.schedule == ScheduleKind::OneFOneB));
        assert!(keep
            .iter()
            .any(|cand| matches!(cand.schedule, ScheduleKind::Interleaved1F1B { .. })));
        // non-GPipe schedules only appear with a real pipeline
        assert!(keep
            .iter()
            .all(|cand| cand.schedule == ScheduleKind::GPipe || cand.par.pp > 1));
    }

    #[test]
    fn full_batch_gpipe_activations_pruned_with_reason() {
        // without a microbatch cap, GPipe's m-deep activation residency
        // overruns the 40 GB A100 floor on deep-pipeline candidates; the
        // prune must be schedule-level (1F1B survives for the same par)
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, pruned) = enumerate(&m, &c, None);
        let act_pruned: Vec<_> = pruned
            .iter()
            .filter(|p| matches!(p.reason, PruneReason::ActivationMemoryExceeded { .. }))
            .collect();
        assert!(!act_pruned.is_empty(), "expected activation-memory prunes");
        for p in &act_pruned {
            let sched = p.schedule.expect("activation prune is schedule-level");
            // some other schedule of the same factorization survives
            assert!(
                keep.iter().any(|k| k.par == p.par && k.schedule != sched),
                "whole factorization tp{}-pp{} lost",
                p.par.tp,
                p.par.pp
            );
        }
    }

    #[test]
    fn cross_node_tp_pruned() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap(); // 16 GPUs, 8/node
        let (keep, pruned) = enumerate(&m, &c, Some(2));
        assert!(keep.iter().all(|cand| cand.par.tp <= 8));
        assert!(pruned
            .iter()
            .any(|p| matches!(p.reason, PruneReason::CrossNodeTp { tp: 16, .. })));
    }

    #[test]
    fn memory_floor_prunes_unsharded_large_model() {
        let m = presets::model("gpt-6.7b").unwrap(); // ~6.7B params
        let c = presets::cluster_hetero(1, 1).unwrap(); // A100 40GB floor
        let (keep, pruned) = enumerate(&m, &c, Some(2));
        // tp*pp == 1 needs ~94 GB/GPU: must be pruned
        assert!(keep.iter().all(|cand| cand.par.tp * cand.par.pp > 1));
        assert!(pruned
            .iter()
            .any(|p| matches!(p.reason, PruneReason::MemoryExceeded { .. })));
    }

    #[test]
    fn homogeneous_cluster_skips_hetero_partitioning() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 2).unwrap();
        let (keep, _) = enumerate(&m, &c, Some(2));
        assert!(keep.iter().all(|cand| cand.partitioning == Partitioning::Uniform));
    }

    #[test]
    fn candidate_keys_are_unique() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, _) = enumerate(&m, &c, Some(2));
        let mut keys: Vec<String> = keep.iter().map(PlanCandidate::key).collect();
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n);
    }
}
