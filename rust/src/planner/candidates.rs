//! Candidate enumeration and pruning for the parallelism-plan search.

use crate::config::cluster::ClusterSpec;
use crate::config::framework::ParallelismSpec;
use crate::config::model::ModelSpec;
use crate::system::collective::RingPolicy;

/// How the model/batch is split across device groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioning {
    /// Equal layer splits and batch shares (the SimAI assumption).
    Uniform,
    /// Non-uniform splits proportional to device-group compute power
    /// (component C1, [`crate::workload::partition::plan_hetero`]).
    HeteroAware,
}

impl Partitioning {
    pub fn name(self) -> &'static str {
        match self {
            Partitioning::Uniform => "uniform",
            Partitioning::HeteroAware => "hetero",
        }
    }
}

/// One candidate deployment plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanCandidate {
    pub par: ParallelismSpec,
    pub partitioning: Partitioning,
    pub ring: RingPolicy,
}

impl PlanCandidate {
    /// Stable human-readable identity; doubles as the deterministic
    /// ranking tie-break.
    pub fn key(&self) -> String {
        format!(
            "tp{}-pp{}-dp{}-{}-{}",
            self.par.tp,
            self.par.pp,
            self.par.dp,
            self.partitioning.name(),
            match self.ring {
                RingPolicy::HeteroAware => "ring:aware",
                RingPolicy::Naive => "ring:naive",
            },
        )
    }
}

/// Why a factorization was excluded from the search (typed so reports
/// never truncate silently).
#[derive(Debug, Clone, thiserror::Error)]
pub enum PruneReason {
    #[error("TP degree {tp} exceeds gpus per node {gpn} (cross-node TP)")]
    CrossNodeTp { tp: u32, gpn: u32 },
    #[error("PP degree {pp} does not divide the {layers} model layers")]
    IndivisibleLayers { pp: u32, layers: u32 },
    #[error("DP degree {dp} exceeds the global batch {batch}")]
    BatchTooSmall { dp: u32, batch: u64 },
    #[error("~{need_gb:.1} GB/GPU exceeds the smallest device memory ({have_gb:.1} GB)")]
    MemoryExceeded { need_gb: f64, have_gb: f64 },
}

/// A factorization that was excluded, and why.
#[derive(Debug, Clone)]
pub struct PrunedCandidate {
    pub par: ParallelismSpec,
    pub reason: PruneReason,
}

/// Coarse per-GPU memory estimate for a (tp, pp) sharding: bf16 weights
/// + fp32 gradients + fp32 Adam moments (8 bytes/param).
pub fn memory_bytes_per_gpu(model: &ModelSpec, tp: u32, pp: u32) -> u64 {
    let per_param = model.dtype_bytes + model.grad_dtype_bytes + 8;
    model.params_per_gpu(tp, pp) * per_param
}

/// Enumerate every valid TP×PP×DP factorization of the cluster's world
/// size, crossed with partitioning strategies and ring policies.
/// Returns `(feasible candidates, pruned factorizations)`. On
/// homogeneous clusters the heterogeneity-aware partitioning reduces to
/// the uniform mapping and is skipped to avoid duplicate work.
pub fn enumerate(
    model: &ModelSpec,
    cluster: &ClusterSpec,
) -> (Vec<PlanCandidate>, Vec<PrunedCandidate>) {
    let world = cluster.total_gpus();
    // smallest node bounds intra-node TP (defensive: validated clusters
    // have uniform gpus_per_node, but don't trust only the first node)
    let gpn = cluster.nodes.iter().map(|n| n.gpus_per_node).min().unwrap_or(0);
    let min_mem = cluster.nodes.iter().map(|n| n.gpu.mem_capacity).min().unwrap_or(0);
    let hetero = !cluster.is_homogeneous();
    let mut keep = Vec::new();
    let mut pruned = Vec::new();
    for tp in 1..=world {
        if world % tp != 0 {
            continue;
        }
        for pp in 1..=(world / tp) {
            if (world / tp) % pp != 0 {
                continue;
            }
            let dp = world / tp / pp;
            let par = ParallelismSpec { tp, pp, dp };
            let reason = if tp > gpn {
                Some(PruneReason::CrossNodeTp { tp, gpn })
            } else if model.num_layers % pp != 0 {
                Some(PruneReason::IndivisibleLayers { pp, layers: model.num_layers })
            } else if u64::from(dp) > model.global_batch {
                Some(PruneReason::BatchTooSmall { dp, batch: model.global_batch })
            } else {
                let need = memory_bytes_per_gpu(model, tp, pp);
                if need > min_mem {
                    Some(PruneReason::MemoryExceeded {
                        need_gb: need as f64 / 1e9,
                        have_gb: min_mem as f64 / 1e9,
                    })
                } else {
                    None
                }
            };
            if let Some(reason) = reason {
                pruned.push(PrunedCandidate { par, reason });
                continue;
            }
            let partitionings: &[Partitioning] = if hetero {
                &[Partitioning::Uniform, Partitioning::HeteroAware]
            } else {
                &[Partitioning::Uniform]
            };
            for &partitioning in partitionings {
                for ring in [RingPolicy::HeteroAware, RingPolicy::Naive] {
                    keep.push(PlanCandidate { par, partitioning, ring });
                }
            }
        }
    }
    (keep, pruned)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn hetero_preset_yields_enough_candidates() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, pruned) = enumerate(&m, &c);
        // acceptance floor for `hetsim plan` on this pair
        assert!(keep.len() >= 8, "only {} candidates", keep.len());
        assert!(!pruned.is_empty());
        // every feasible factorization divides the world
        for cand in &keep {
            assert_eq!(cand.par.world_size(), c.total_gpus());
        }
        // the uniform default plan is in the candidate set
        let def = crate::simulator::infer_parallelism(&m, &c).unwrap();
        assert!(keep.iter().any(|cand| {
            cand.par == def
                && cand.partitioning == Partitioning::Uniform
                && cand.ring == RingPolicy::HeteroAware
        }));
    }

    #[test]
    fn cross_node_tp_pruned() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap(); // 16 GPUs, 8/node
        let (keep, pruned) = enumerate(&m, &c);
        assert!(keep.iter().all(|cand| cand.par.tp <= 8));
        assert!(pruned
            .iter()
            .any(|p| matches!(p.reason, PruneReason::CrossNodeTp { tp: 16, .. })));
    }

    #[test]
    fn memory_floor_prunes_unsharded_large_model() {
        let m = presets::model("gpt-6.7b").unwrap(); // ~6.7B params
        let c = presets::cluster_hetero(1, 1).unwrap(); // A100 40GB floor
        let (keep, pruned) = enumerate(&m, &c);
        // tp*pp == 1 needs ~94 GB/GPU: must be pruned
        assert!(keep.iter().all(|cand| cand.par.tp * cand.par.pp > 1));
        assert!(pruned
            .iter()
            .any(|p| matches!(p.reason, PruneReason::MemoryExceeded { .. })));
    }

    #[test]
    fn homogeneous_cluster_skips_hetero_partitioning() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 2).unwrap();
        let (keep, _) = enumerate(&m, &c);
        assert!(keep.iter().all(|cand| cand.partitioning == Partitioning::Uniform));
    }

    #[test]
    fn candidate_keys_are_unique() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, _) = enumerate(&m, &c);
        let mut keys: Vec<String> = keep.iter().map(PlanCandidate::key).collect();
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n);
    }
}
