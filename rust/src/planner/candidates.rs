//! Candidate enumeration and pruning for the parallelism-plan search.
//!
//! Every feasible TP×PP×DP factorization is crossed with partitioning
//! strategies, ring policies and pipeline schedules; on heterogeneous
//! clusters the space is additionally extended with **variable
//! per-group TP layouts** ([`TpLayout::PerNode`]): each node becomes
//! one device group whose GPUs are split into an intra-node pipeline of
//! TP groups that need not match across groups — the paper's Fig-3
//! shape (TP=3 → TP=1 on the H100 node vs TP=4 on the A100 node),
//! which forces resharding at DP-sync time and is unreachable from any
//! global TP×PP×DP factorization.
//!
//! Pruning is typed and two-level: whole factorizations/layouts fall to
//! structural reasons (cross-node TP, indivisible layers, batch floor,
//! weights+optimizer memory, infeasible proportional splits),
//! individual `(factorization, schedule)` pairs fall when the
//! schedule's peak-activation estimate pushes the smallest device over
//! its memory capacity — the schedule × heterogeneity interaction the
//! paper's homogeneous baselines cannot express.

use crate::config::cluster::ClusterSpec;
use crate::config::framework::{FrameworkSpec, ParallelismSpec};
use crate::config::model::ModelSpec;
use crate::system::collective::RingPolicy;
use crate::workload::partition::{plan_hetero, plan_variable_tp, SplitError};
use crate::workload::schedule::{ScheduleKind, ACT_BYTES_PER_LAYER_FACTOR};

/// How the model/batch is split across device groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioning {
    /// Equal layer splits and batch shares (the SimAI assumption).
    Uniform,
    /// Non-uniform splits proportional to device-group compute power
    /// (component C1, [`crate::workload::partition::plan_hetero`]).
    HeteroAware,
}

impl Partitioning {
    /// Stable name used in candidate keys.
    pub fn name(self) -> &'static str {
        match self {
            Partitioning::Uniform => "uniform",
            Partitioning::HeteroAware => "hetero",
        }
    }
}

/// How ranks are laid out into TP groups.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TpLayout {
    /// The classic global TP×PP×DP grid (TP fastest, then PP, then DP).
    Uniform,
    /// Variable per-group TP: one device group per node, whose pipeline
    /// stages are the node's GPUs split into the given TP degrees
    /// (`[3, 1]` = a TP=3 stage feeding a TP=1 stage, paper Fig 3).
    /// One entry per cluster node, in rank order.
    PerNode(Vec<Vec<u32>>),
}

impl TpLayout {
    /// Stable token used in candidate keys: `grid` for the uniform
    /// layout, `var(...)` with run-length-compressed per-node splits
    /// otherwise (`var(3+1,4)`, `var(2x7+1)`).
    pub fn token(&self) -> String {
        match self {
            TpLayout::Uniform => "grid".into(),
            TpLayout::PerNode(splits) => {
                let mut out: Vec<String> = Vec::new();
                let mut i = 0;
                while i < splits.len() {
                    let mut j = i;
                    while j < splits.len() && splits[j] == splits[i] {
                        j += 1;
                    }
                    let split = splits[i]
                        .iter()
                        .map(u32::to_string)
                        .collect::<Vec<_>>()
                        .join("+");
                    if j - i > 1 {
                        out.push(format!("{}x{}", j - i, split));
                    } else {
                        out.push(split);
                    }
                    i = j;
                }
                format!("var({})", out.join(","))
            }
        }
    }
}

/// One candidate deployment plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanCandidate {
    /// Parallelism degrees. For [`TpLayout::PerNode`] layouts these are
    /// informational maxima (max stage TP, max pipeline depth, group
    /// count) — the layout itself is authoritative.
    pub par: ParallelismSpec,
    /// How ranks form TP groups (grid or variable per-node splits).
    pub layout: TpLayout,
    /// How layers/batch are split across device groups.
    pub partitioning: Partitioning,
    /// Collective ring-ordering policy.
    pub ring: RingPolicy,
    /// Pipeline schedule ordering each group's microbatches.
    pub schedule: ScheduleKind,
}

/// The layout head segment shared by [`PlanCandidate::key`] and
/// [`PrunedCandidate::key_head`], so ranked and pruned report lines can
/// never drift apart.
fn layout_head(par: &ParallelismSpec, layout: &TpLayout) -> String {
    match layout {
        TpLayout::Uniform => format!("tp{}-pp{}-dp{}", par.tp, par.pp, par.dp),
        TpLayout::PerNode(_) => layout.token(),
    }
}

impl PlanCandidate {
    /// Stable human-readable identity; doubles as the deterministic
    /// ranking tie-break.
    pub fn key(&self) -> String {
        format!(
            "{}-{}-{}-{}",
            layout_head(&self.par, &self.layout),
            self.partitioning.name(),
            match self.ring {
                RingPolicy::HeteroAware => "ring:aware",
                RingPolicy::Naive => "ring:naive",
            },
            self.schedule.name(),
        )
    }

    /// Materialize the candidate into the concrete device-group mapping
    /// it describes — the spec the evaluator simulates and the refiner
    /// ([`crate::planner::refine`]) starts from.
    pub fn framework(
        &self,
        model: &ModelSpec,
        cluster: &ClusterSpec,
    ) -> anyhow::Result<FrameworkSpec> {
        let fw = match &self.layout {
            TpLayout::Uniform => match self.partitioning {
                Partitioning::Uniform => FrameworkSpec::uniform(model, cluster, self.par)?,
                Partitioning::HeteroAware => plan_hetero(model, cluster, self.par)?,
            },
            TpLayout::PerNode(splits) => plan_variable_tp(
                model,
                cluster,
                splits,
                self.partitioning == Partitioning::HeteroAware,
            )?,
        };
        Ok(fw.with_schedule(self.schedule))
    }
}

/// Why a factorization (or one of its schedules) was excluded from the
/// search (typed so reports never truncate silently).
#[derive(Debug, Clone, thiserror::Error)]
pub enum PruneReason {
    /// TP groups may not span node boundaries (NVLink domain).
    #[error("TP degree {tp} exceeds gpus per node {gpn} (cross-node TP)")]
    CrossNodeTp {
        /// Rejected TP degree.
        tp: u32,
        /// GPUs per node of the smallest node.
        gpn: u32,
    },
    /// On mixed-node-size clusters, contiguous TP blocks stay inside
    /// node boundaries only when the TP degree divides every node's
    /// GPU count (equivalently, the node-size GCD) — a degree that
    /// fits the smallest node can still straddle a boundary.
    #[error(
        "TP degree {tp} does not divide every node size on a \
         mixed-node-size cluster (TP blocks would straddle node boundaries)"
    )]
    MisalignedTp {
        /// Rejected TP degree.
        tp: u32,
    },
    /// The uniform mapping needs `layers % pp == 0`.
    #[error("PP degree {pp} does not divide the {layers} model layers")]
    IndivisibleLayers {
        /// Rejected PP degree.
        pp: u32,
        /// Model layer count.
        layers: u32,
    },
    /// Each DP replica needs at least one sample per iteration.
    #[error("DP degree {dp} exceeds the global batch {batch}")]
    BatchTooSmall {
        /// Rejected DP degree.
        dp: u32,
        /// Global batch size.
        batch: u64,
    },
    /// Weights + gradients + optimizer state exceed the smallest device.
    #[error("~{need_gb:.1} GB/GPU exceeds the smallest device memory ({have_gb:.1} GB)")]
    MemoryExceeded {
        /// Estimated bytes per GPU, in GB.
        need_gb: f64,
        /// Smallest device capacity, in GB.
        have_gb: f64,
    },
    /// Weights + the schedule's peak activation residency exceed the
    /// smallest device (schedule-level prune: other schedules of the
    /// same factorization may survive; the schedule is carried by
    /// [`PrunedCandidate::schedule`]).
    #[error(
        "~{need_gb:.1} GB/GPU incl. schedule activations exceeds the smallest \
         device memory ({have_gb:.1} GB)"
    )]
    ActivationMemoryExceeded {
        /// Estimated bytes per GPU (weights + activations), in GB.
        need_gb: f64,
        /// Smallest device capacity, in GB.
        have_gb: f64,
    },
    /// A layer or batch proportional split is infeasible for the
    /// layout's stage/group counts (more stages than layers, more
    /// groups than batch samples). Carries the typed
    /// [`SplitError`] instead of letting `plan_hetero` /
    /// `plan_variable_tp` abort the whole search at evaluation time.
    #[error(transparent)]
    Unsplittable(#[from] SplitError),
}

impl PruneReason {
    /// Stable kebab-case tag of the variant (parameters dropped), for
    /// the per-reason prune counts in
    /// [`super::search::PlanSearchReport`].
    pub fn label(&self) -> &'static str {
        match self {
            PruneReason::CrossNodeTp { .. } => "cross-node-tp",
            PruneReason::MisalignedTp { .. } => "misaligned-tp",
            PruneReason::IndivisibleLayers { .. } => "indivisible-layers",
            PruneReason::BatchTooSmall { .. } => "batch-too-small",
            PruneReason::MemoryExceeded { .. } => "memory-exceeded",
            PruneReason::ActivationMemoryExceeded { .. } => "activation-memory",
            PruneReason::Unsplittable(_) => "unsplittable",
        }
    }
}

/// A factorization/layout (or one of its schedules) that was excluded,
/// and why.
#[derive(Debug, Clone)]
pub struct PrunedCandidate {
    /// The excluded parallelism degrees (informational maxima for
    /// variable layouts).
    pub par: ParallelismSpec,
    /// The excluded rank layout.
    pub layout: TpLayout,
    /// The specific schedule excluded, when the prune is
    /// schedule-level (`None` = the whole factorization fell).
    pub schedule: Option<ScheduleKind>,
    /// Typed exclusion reason.
    pub reason: PruneReason,
}

impl PrunedCandidate {
    /// Stable display identity of the excluded layout (the same head
    /// segment [`PlanCandidate::key`] uses).
    pub fn key_head(&self) -> String {
        layout_head(&self.par, &self.layout)
    }
}

/// Coarse per-GPU memory estimate for a (tp, pp) sharding: bf16 weights
/// + fp32 gradients + fp32 Adam moments (8 bytes/param).
pub fn memory_bytes_per_gpu(model: &ModelSpec, tp: u32, pp: u32) -> u64 {
    let per_param = model.dtype_bytes + model.grad_dtype_bytes + 8;
    model.params_per_gpu(tp, pp) * per_param
}

/// Pipeline schedules worth exploring for a factorization: GPipe
/// always; 1F1B and interleaved (vpp = 2) once there is a real pipeline
/// (and, for interleaved, at least 2 layers per stage to chunk).
pub fn schedules_for(model: &ModelSpec, pp: u32) -> Vec<ScheduleKind> {
    let mut s = vec![ScheduleKind::GPipe];
    if pp > 1 {
        s.push(ScheduleKind::OneFOneB);
        if model.num_layers / pp >= 2 {
            s.push(ScheduleKind::Interleaved1F1B { vpp: 2 });
        }
    }
    s
}

/// Intra-node pipeline splits of `gpn` GPUs worth exploring: the whole
/// node as one TP group (`[gpn]`) plus every two-stage split
/// `[gpn - k, k]` for `k ≤ gpn/2` — the space containing the paper's
/// Fig-3 `[3, 1]` split. Deeper intra-node pipelines trade more bubbles
/// for no extra resharding freedom, so they are not enumerated; the
/// refiner can still rebalance layers within the two stages.
pub fn node_splits(gpn: u32) -> Vec<Vec<u32>> {
    let mut out = vec![vec![gpn]];
    for small in 1..=gpn / 2 {
        out.push(vec![gpn - small, small]);
    }
    out
}

/// Enumerate every valid TP×PP×DP factorization of the cluster's world
/// size, crossed with partitioning strategies, ring policies and
/// pipeline schedules. On heterogeneous clusters, additionally
/// enumerate variable per-group TP layouts ([`TpLayout::PerNode`]):
/// every assignment of one [`node_splits`] entry per GPU architecture
/// (all nodes of one architecture share a split), skipping the
/// assignment that collapses to the uniform `tp = gpn, pp = 1` grid.
/// Variable layouts run GPipe only (their per-group pipeline depths may
/// differ, and the Fig-3 reference uses GPipe).
///
/// Returns `(feasible candidates, pruned factorizations)`. On
/// homogeneous clusters the heterogeneity-aware partitioning reduces to
/// the uniform mapping and is skipped to avoid duplicate work; on
/// `pp == 1` factorizations the schedules collapse to GPipe for the
/// same reason.
///
/// `microbatch_limit` mirrors the evaluation's
/// [`crate::workload::aicb::WorkloadOptions::microbatch_limit`]: the
/// schedule peak-activation estimate is computed for the microbatch
/// count that will actually be simulated (`None` = the full batch, the
/// honest deployment-feasibility check).
pub fn enumerate(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    microbatch_limit: Option<u64>,
) -> (Vec<PlanCandidate>, Vec<PrunedCandidate>) {
    enumerate_with_memory(model, cluster, microbatch_limit, true)
}

/// [`enumerate`] with the device-memory prunes made optional.
///
/// `check_memory = false` skips the weights+optimizer and
/// peak-activation prunes (structural prunes still apply). The search
/// falls back to this when *no* candidate fits the memory model — the
/// paper's own Fig-3 scenario is such a case (Llama-2 70B with full
/// Adam state cannot fit 8 GPUs, yet the figure deploys it as an
/// illustration), and a ranking with a visible "memory model relaxed"
/// note beats refusing to plan.
pub fn enumerate_with_memory(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    microbatch_limit: Option<u64>,
    check_memory: bool,
) -> (Vec<PlanCandidate>, Vec<PrunedCandidate>) {
    let world = cluster.total_gpus();
    // the smallest node bounds intra-node TP — every node must be able
    // to host a full TP group
    let gpn = cluster.min_gpus_per_node();
    // on mixed node sizes a tp <= gpn block can still straddle a node
    // boundary; blocks align iff tp divides every node size (the GCD)
    let uniform_sizes = cluster.uniform_gpus_per_node().is_some();
    let size_gcd = cluster.gcd_gpus_per_node().max(1);
    let min_mem = cluster.nodes.iter().map(|n| n.gpu.mem_capacity).min().unwrap_or(0);
    // mixed node *sizes* open the variable-layout space too: per-node
    // TP splits are the only layouts whose TP groups are guaranteed to
    // align with node boundaries on such clusters
    let hetero = !cluster.is_homogeneous() || !uniform_sizes;
    let mut keep = Vec::new();
    let mut pruned = Vec::new();
    for tp in 1..=world {
        if world % tp != 0 {
            continue;
        }
        for pp in 1..=(world / tp) {
            if (world / tp) % pp != 0 {
                continue;
            }
            let dp = world / tp / pp;
            let par = ParallelismSpec { tp, pp, dp };
            let weights = memory_bytes_per_gpu(model, tp, pp);
            let reason = if tp > gpn {
                Some(PruneReason::CrossNodeTp { tp, gpn })
            } else if !uniform_sizes && size_gcd % tp != 0 {
                Some(PruneReason::MisalignedTp { tp })
            } else if model.num_layers % pp != 0 {
                Some(PruneReason::IndivisibleLayers { pp, layers: model.num_layers })
            } else if u64::from(dp) > model.global_batch {
                Some(PruneReason::BatchTooSmall { dp, batch: model.global_batch })
            } else if check_memory && weights > min_mem {
                Some(PruneReason::MemoryExceeded {
                    need_gb: weights as f64 / 1e9,
                    have_gb: min_mem as f64 / 1e9,
                })
            } else {
                None
            };
            if let Some(reason) = reason {
                pruned.push(PrunedCandidate {
                    par,
                    layout: TpLayout::Uniform,
                    schedule: None,
                    reason,
                });
                continue;
            }
            // microbatches one device group will actually simulate
            // (uniform-split approximation for the estimate)
            let m_full = (model.global_batch / (u64::from(dp) * model.micro_batch)).max(1);
            let m_eff = microbatch_limit.map_or(m_full, |l| m_full.min(l.max(1)));
            let partitionings: &[Partitioning] = if hetero {
                &[Partitioning::Uniform, Partitioning::HeteroAware]
            } else {
                &[Partitioning::Uniform]
            };
            for schedule in schedules_for(model, pp) {
                // schedule-level memory prune: weights + peak activations
                let need = weights + schedule.peak_activation_bytes(model, tp, pp, m_eff);
                if check_memory && need > min_mem {
                    pruned.push(PrunedCandidate {
                        par,
                        layout: TpLayout::Uniform,
                        schedule: Some(schedule),
                        reason: PruneReason::ActivationMemoryExceeded {
                            need_gb: need as f64 / 1e9,
                            have_gb: min_mem as f64 / 1e9,
                        },
                    });
                    continue;
                }
                for &partitioning in partitionings {
                    for ring in [RingPolicy::HeteroAware, RingPolicy::Naive] {
                        keep.push(PlanCandidate {
                            par,
                            layout: TpLayout::Uniform,
                            partitioning,
                            ring,
                            schedule,
                        });
                    }
                }
            }
        }
    }
    if hetero {
        enumerate_variable(model, cluster, microbatch_limit, check_memory, &mut keep, &mut pruned);
    }
    (keep, pruned)
}

/// The variable-layout arm of [`enumerate`]: one device group per node,
/// per-**node-class** intra-node TP splits (a class is one `(GPU
/// architecture, node size)` pair, so 4-GPU Ampere nodes and 8-GPU
/// Hopper nodes each pick from their own [`node_splits`] menu),
/// feasibility-checked with the same typed prunes as the grid arm.
fn enumerate_variable(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    microbatch_limit: Option<u64>,
    check_memory: bool,
    keep: &mut Vec<PlanCandidate>,
    pruned: &mut Vec<PrunedCandidate>,
) {
    if cluster.min_gpus_per_node() == 0 {
        return;
    }
    // node classes in first-appearance order: all nodes of one class
    // share a split (on uniform-size clusters classes == architectures,
    // reproducing the pre-fabric enumeration exactly)
    let mut classes: Vec<(&str, u32)> = Vec::new();
    for n in &cluster.nodes {
        let key = (n.gpu.name.as_str(), n.gpus_per_node);
        if !classes.contains(&key) {
            classes.push(key);
        }
    }
    let options: Vec<Vec<Vec<u32>>> =
        classes.iter().map(|(_, g)| node_splits(*g)).collect();
    // combo-invariant node → class index map, resolved once
    let node_class: Vec<usize> = cluster
        .nodes
        .iter()
        .map(|n| {
            let key = (n.gpu.name.as_str(), n.gpus_per_node);
            classes.iter().position(|c| *c == key).unwrap_or(0)
        })
        .collect();
    // cartesian product: one split choice per class, in stable
    // (first-appearance class, split-index) order
    let mut combos: Vec<Vec<usize>> = vec![Vec::new()];
    for opts in &options {
        combos = combos
            .into_iter()
            .flat_map(|c| {
                (0..opts.len()).map(move |i| {
                    let mut next = c.clone();
                    next.push(i);
                    next
                })
            })
            .collect();
    }
    let per_param = model.dtype_bytes + model.grad_dtype_bytes + 8;
    // every class on one whole-node TP group duplicates the uniform
    // `tp = gpn, pp = 1` grid — but only when one grid can express it
    // (uniform node sizes); on mixed sizes it is a genuinely new layout
    let skip_whole_node = cluster.uniform_gpus_per_node().is_some();
    for combo in combos {
        if skip_whole_node && combo.iter().all(|i| *i == 0) {
            continue;
        }
        let splits: Vec<Vec<u32>> =
            node_class.iter().map(|&a| options[a][combo[a]].clone()).collect();
        let layout = TpLayout::PerNode(splits.clone());
        let max_tp = splits.iter().flatten().copied().max().unwrap_or(1);
        let max_pp = splits.iter().map(Vec::len).max().unwrap_or(1) as u32;
        let par = ParallelismSpec { tp: max_tp, pp: max_pp, dp: splits.len() as u32 };

        // Feasibility is checked per partitioning on the spec
        // `plan_variable_tp` actually materializes — the uniform and
        // proportional splits put very different loads on each stage,
        // and sharing the builder makes the prune structurally unable
        // to disagree with what evaluation will simulate.
        for partitioning in [Partitioning::Uniform, Partitioning::HeteroAware] {
            let spec = match plan_variable_tp(
                model,
                cluster,
                &splits,
                partitioning == Partitioning::HeteroAware,
            ) {
                Ok(spec) => spec,
                Err(e) => {
                    // enumerator-built splits are structurally valid, so
                    // the only expected failures are the typed split
                    // errors (layers < stages, batch < groups)
                    if let Some(se) = e.downcast_ref::<SplitError>() {
                        pruned.push(PrunedCandidate {
                            par,
                            layout: layout.clone(),
                            schedule: None,
                            reason: PruneReason::Unsplittable(*se),
                        });
                    } else {
                        debug_assert!(false, "unexpected plan_variable_tp error: {e:#}");
                    }
                    continue;
                }
            };

            // per-GPU memory on every materialized stage: weight share
            // plus GPipe activation residency for the microbatches that
            // will actually be simulated
            let mut mem_reason = None;
            if check_memory {
                'mem: for g in &spec.groups {
                    let node = &cluster.nodes[g.id as usize];
                    let m_full = (g.batch_share / g.micro_batch.max(1)).max(1);
                    let m_eff = microbatch_limit.map_or(m_full, |l| m_full.min(l.max(1)));
                    for stage in &g.stages {
                        let tp = u64::from(stage.tp().max(1));
                        let layers = u64::from(stage.num_layers);
                        let weights = model.param_count() * per_param * layers
                            / (u64::from(model.num_layers) * tp);
                        let act = m_eff
                            * g.micro_batch
                            * model.seq_len
                            * model.hidden_size
                            * ACT_BYTES_PER_LAYER_FACTOR
                            * layers
                            / tp;
                        let have = node.gpu.mem_capacity;
                        // distinguish the two overruns like the grid
                        // arm: weights+optimizer alone (no microbatch
                        // knob can help) vs weights + schedule
                        // activations (GPipe, the layout's only
                        // schedule)
                        if weights > have {
                            mem_reason = Some((
                                None,
                                PruneReason::MemoryExceeded {
                                    need_gb: weights as f64 / 1e9,
                                    have_gb: have as f64 / 1e9,
                                },
                            ));
                            break 'mem;
                        }
                        if weights + act > have {
                            mem_reason = Some((
                                Some(ScheduleKind::GPipe),
                                PruneReason::ActivationMemoryExceeded {
                                    need_gb: (weights + act) as f64 / 1e9,
                                    have_gb: have as f64 / 1e9,
                                },
                            ));
                            break 'mem;
                        }
                    }
                }
            }
            if let Some((schedule, reason)) = mem_reason {
                pruned.push(PrunedCandidate {
                    par,
                    layout: layout.clone(),
                    schedule,
                    reason,
                });
                continue;
            }

            for ring in [RingPolicy::HeteroAware, RingPolicy::Naive] {
                keep.push(PlanCandidate {
                    par,
                    layout: layout.clone(),
                    partitioning,
                    ring,
                    schedule: ScheduleKind::GPipe,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn hetero_preset_yields_enough_candidates() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, pruned) = enumerate(&m, &c, Some(2));
        // acceptance floor for `hetsim plan` on this pair
        assert!(keep.len() >= 8, "only {} candidates", keep.len());
        assert!(!pruned.is_empty());
        // every feasible grid factorization divides the world
        for cand in &keep {
            if cand.layout == TpLayout::Uniform {
                assert_eq!(cand.par.world_size(), c.total_gpus());
            }
        }
        // the uniform default plan is in the candidate set
        let def = crate::simulator::infer_parallelism(&m, &c).unwrap();
        assert!(keep.iter().any(|cand| {
            cand.par == def
                && cand.layout == TpLayout::Uniform
                && cand.partitioning == Partitioning::Uniform
                && cand.ring == RingPolicy::HeteroAware
                && cand.schedule == ScheduleKind::GPipe
        }));
    }

    #[test]
    fn variable_layouts_enumerated_on_hetero_cluster() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, _) = enumerate(&m, &c, Some(2));
        let var: Vec<_> =
            keep.iter().filter(|cand| matches!(cand.layout, TpLayout::PerNode(_))).collect();
        assert!(!var.is_empty(), "no variable-TP candidates");
        // variable layouts run GPipe only, in both partitionings
        assert!(var.iter().all(|cand| cand.schedule == ScheduleKind::GPipe));
        assert!(var.iter().any(|cand| cand.partitioning == Partitioning::HeteroAware));
        assert!(var.iter().any(|cand| cand.partitioning == Partitioning::Uniform));
        // the per-arch assignment where both archs keep one TP group is
        // skipped (it duplicates the tp=8, pp=1 grid)
        assert!(var.iter().all(|cand| match &cand.layout {
            TpLayout::PerNode(splits) => splits.iter().any(|s| s.len() > 1),
            TpLayout::Uniform => unreachable!(),
        }));
    }

    #[test]
    fn fig3_layout_is_in_the_candidate_space() {
        // Llama-2 70B with full Adam state cannot fit 8 GPUs, so the
        // strict enumeration prunes *everything* on the Fig-3 cluster —
        // with typed reasons, never silently...
        let m = crate::workload::partition::fig3_model().unwrap();
        let c = crate::workload::partition::fig3_cluster().unwrap();
        let (keep, pruned) = enumerate(&m, &c, Some(2));
        assert!(keep.is_empty(), "fig3 is memory-infeasible under full Adam state");
        assert!(pruned.iter().all(|p| matches!(
            p.reason,
            PruneReason::MemoryExceeded { .. }
                | PruneReason::ActivationMemoryExceeded { .. }
                | PruneReason::CrossNodeTp { .. }
        )));
        // ...and the memory-relaxed fallback (what `search` uses) must
        // contain the paper's Fig-3 layout ([3,1] on the H100 node, [4]
        // on the A100 node)
        let (keep, _) = enumerate_with_memory(&m, &c, Some(2), false);
        let want = TpLayout::PerNode(vec![vec![3, 1], vec![4]]);
        assert!(
            keep.iter().any(|cand| cand.layout == want
                && cand.partitioning == Partitioning::HeteroAware
                && cand.schedule == ScheduleKind::GPipe),
            "fig3 layout missing from {} candidates",
            keep.len()
        );
    }

    #[test]
    fn mixed_node_sizes_enumerate_per_class_variable_layouts() {
        // 4-GPU ampere node beside an 8-GPU hopper node: classes are
        // (A100, 4) and (H100, 8), each with its own split menu; the
        // whole-node assignment [4],[8] is kept (no grid expresses it)
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.global_batch = 16;
        m.micro_batch = 8;
        let mut c = presets::cluster_hetero(1, 1).unwrap();
        c.nodes[0].gpus_per_node = 4;
        let (keep, _) = enumerate(&m, &c, Some(1));
        let var: Vec<_> = keep
            .iter()
            .filter_map(|cand| match &cand.layout {
                TpLayout::PerNode(s) => Some(s),
                TpLayout::Uniform => None,
            })
            .collect();
        assert!(!var.is_empty(), "no variable layouts on a mixed-size cluster");
        // every layout matches each node's actual GPU count
        for splits in &var {
            assert_eq!(splits.len(), 2);
            assert_eq!(splits[0].iter().sum::<u32>(), 4);
            assert_eq!(splits[1].iter().sum::<u32>(), 8);
        }
        // the whole-node [4],[8] layout is in the space
        assert!(var.iter().any(|s| **s == vec![vec![4], vec![8]]));
        // grid candidates are bounded by the smallest node AND keep TP
        // blocks aligned with node boundaries (tp divides every size)
        for cand in keep.iter().filter(|cand| cand.layout == TpLayout::Uniform) {
            assert!(cand.par.tp <= 4);
            assert_eq!(c.gcd_gpus_per_node() % cand.par.tp, 0, "tp {}", cand.par.tp);
        }
    }

    #[test]
    fn straddling_tp_blocks_pruned_as_misaligned_on_mixed_sizes() {
        // nodes of 3 and 5 GPUs: world = 8, min gpn = 3 — tp = 2 fits
        // the smallest node but its contiguous blocks straddle the
        // node boundary at rank 3, so it must fall with a typed reason
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.global_batch = 16;
        m.micro_batch = 8;
        let mut c = presets::cluster_hetero(1, 1).unwrap();
        c.nodes[0].gpus_per_node = 3;
        c.nodes[1].gpus_per_node = 5;
        let (keep, pruned) = enumerate(&m, &c, Some(1));
        assert!(keep
            .iter()
            .all(|cand| cand.layout != TpLayout::Uniform || cand.par.tp == 1));
        assert!(pruned
            .iter()
            .any(|p| matches!(p.reason, PruneReason::MisalignedTp { tp: 2 })));
    }

    #[test]
    fn variable_layouts_homogeneous_cluster_skipped() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 2).unwrap();
        let (keep, _) = enumerate(&m, &c, Some(2));
        assert!(keep.iter().all(|cand| cand.layout == TpLayout::Uniform));
    }

    #[test]
    fn shallow_model_variable_layouts_pruned_with_typed_split_error() {
        // 1 layer cannot cover a 2-stage intra-node pipeline: the
        // two-stage layouts must fall with PruneReason::Unsplittable,
        // not abort the search
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 1;
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, pruned) = enumerate(&m, &c, Some(2));
        assert!(keep.iter().all(|cand| cand.layout == TpLayout::Uniform));
        assert!(pruned
            .iter()
            .any(|p| matches!(p.reason, PruneReason::Unsplittable(_))));
    }

    #[test]
    fn layout_tokens_compress_runs() {
        assert_eq!(TpLayout::Uniform.token(), "grid");
        assert_eq!(TpLayout::PerNode(vec![vec![3, 1], vec![4]]).token(), "var(3+1,4)");
        assert_eq!(
            TpLayout::PerNode(vec![vec![7, 1], vec![7, 1], vec![8]]).token(),
            "var(2x7+1,8)"
        );
    }

    #[test]
    fn all_three_schedule_kinds_enumerated() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, _) = enumerate(&m, &c, Some(2));
        assert!(keep.iter().any(|cand| cand.schedule == ScheduleKind::GPipe));
        assert!(keep.iter().any(|cand| cand.schedule == ScheduleKind::OneFOneB));
        assert!(keep
            .iter()
            .any(|cand| matches!(cand.schedule, ScheduleKind::Interleaved1F1B { .. })));
        // non-GPipe schedules only appear with a real pipeline
        assert!(keep
            .iter()
            .all(|cand| cand.schedule == ScheduleKind::GPipe || cand.par.pp > 1));
    }

    #[test]
    fn full_batch_gpipe_activations_pruned_with_reason() {
        // without a microbatch cap, GPipe's m-deep activation residency
        // overruns the 40 GB A100 floor on deep-pipeline candidates; the
        // prune must be schedule-level (1F1B survives for the same par)
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, pruned) = enumerate(&m, &c, None);
        let act_pruned: Vec<_> = pruned
            .iter()
            .filter(|p| {
                p.layout == TpLayout::Uniform
                    && matches!(p.reason, PruneReason::ActivationMemoryExceeded { .. })
            })
            .collect();
        assert!(!act_pruned.is_empty(), "expected activation-memory prunes");
        for p in &act_pruned {
            let sched = p.schedule.expect("activation prune is schedule-level");
            // some other schedule of the same factorization survives
            assert!(
                keep.iter().any(|k| k.par == p.par && k.schedule != sched),
                "whole factorization tp{}-pp{} lost",
                p.par.tp,
                p.par.pp
            );
        }
    }

    #[test]
    fn cross_node_tp_pruned() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap(); // 16 GPUs, 8/node
        let (keep, pruned) = enumerate(&m, &c, Some(2));
        assert!(keep.iter().all(|cand| cand.par.tp <= 8));
        assert!(pruned
            .iter()
            .any(|p| matches!(p.reason, PruneReason::CrossNodeTp { tp: 16, .. })));
    }

    #[test]
    fn memory_floor_prunes_unsharded_large_model() {
        let m = presets::model("gpt-6.7b").unwrap(); // ~6.7B params
        let c = presets::cluster_hetero(1, 1).unwrap(); // A100 40GB floor
        let (keep, pruned) = enumerate(&m, &c, Some(2));
        // tp*pp == 1 needs ~94 GB/GPU: must be pruned
        assert!(keep.iter().all(|cand| cand.par.tp * cand.par.pp > 1));
        assert!(pruned
            .iter()
            .any(|p| matches!(p.reason, PruneReason::MemoryExceeded { .. })));
    }

    #[test]
    fn homogeneous_cluster_skips_hetero_partitioning() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 2).unwrap();
        let (keep, _) = enumerate(&m, &c, Some(2));
        assert!(keep.iter().all(|cand| cand.partitioning == Partitioning::Uniform));
    }

    #[test]
    fn candidate_keys_are_unique() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let (keep, _) = enumerate(&m, &c, Some(2));
        let mut keys: Vec<String> = keep.iter().map(PlanCandidate::key).collect();
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n);
    }
}
