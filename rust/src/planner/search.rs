//! Concurrent candidate evaluation and deterministic ranking.
//!
//! Every candidate is scored by building and running one full simulated
//! iteration (workload generation → cost table → dense compile → event
//! loop). Workers pull candidates off a shared atomic counter inside
//! `std::thread::scope`; the model/cluster inputs are borrowed
//! immutably by all threads. Because each simulation is deterministic
//! and the final sort uses (iteration time, candidate key), the ranked
//! output is byte-identical no matter how many workers ran the sweep.

use crate::config::cluster::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::simulator::{infer_parallelism, SimulationBuilder};
use crate::system::collective::RingPolicy;
use crate::util::par::parallel_map;
use crate::util::table::Table;
use crate::util::units::Time;
use crate::workload::aicb::WorkloadOptions;
use crate::workload::schedule::ScheduleKind;

use super::candidates::{enumerate, Partitioning, PlanCandidate, PrunedCandidate};

/// Search knobs.
#[derive(Debug, Clone)]
pub struct PlanOptions {
    /// Cap microbatches per device group during candidate evaluation.
    /// Plan *ranking* needs relative ordering, not full-batch absolute
    /// times; `None` simulates every microbatch.
    pub microbatch_limit: Option<u64>,
    /// Worker threads (0 = one per available core).
    pub threads: usize,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions { microbatch_limit: Some(2), threads: 0 }
    }
}

/// One scored candidate.
#[derive(Debug, Clone)]
pub struct EvaluatedPlan {
    /// The candidate that was simulated.
    pub candidate: PlanCandidate,
    /// Predicted iteration time (the ranking criterion).
    pub iteration_time: Time,
    /// Summed per-rank compute busy time (the compute side of the
    /// compute/comm breakdown).
    pub compute_busy: Time,
    /// Summed collective busy time.
    pub comm_busy: Time,
    /// Network flows completed in the simulated iteration.
    pub flows_completed: usize,
    /// Discrete events the simulation processed.
    pub events_processed: u64,
}

/// The full search result.
#[derive(Debug)]
pub struct PlanSearchReport {
    /// Candidates ranked by predicted iteration time (stable key
    /// tie-break) — byte-identical across runs and worker counts.
    pub ranked: Vec<EvaluatedPlan>,
    /// Factorizations / schedules excluded before evaluation, with
    /// typed reasons.
    pub pruned: Vec<PrunedCandidate>,
    /// Candidates that failed to build or run, with the error text
    /// (kept visible rather than silently dropped).
    pub failed: Vec<(PlanCandidate, String)>,
    /// The uniform default plan ([`infer_parallelism`] + uniform
    /// mapping + hetero-aware rings) under the same options.
    pub baseline: EvaluatedPlan,
}

impl PlanSearchReport {
    /// The top-ranked plan.
    pub fn best(&self) -> &EvaluatedPlan {
        &self.ranked[0]
    }

    /// Render the ranked table (top `limit` rows, 0 = all) plus a
    /// summary line.
    pub fn render(&self, limit: usize) -> String {
        let mut t = Table::new(
            "Ranked parallelism plans (one simulated iteration)",
            &["rank", "plan", "iteration", "compute-busy", "comm-busy", "flows", "vs default"],
        );
        let base = self.baseline.iteration_time.as_secs();
        let shown =
            if limit == 0 { self.ranked.len() } else { limit.min(self.ranked.len()) };
        for (i, ev) in self.ranked[..shown].iter().enumerate() {
            let speedup = base / ev.iteration_time.as_secs();
            t.row(vec![
                (i + 1).to_string(),
                ev.candidate.key(),
                ev.iteration_time.human(),
                ev.compute_busy.human(),
                ev.comm_busy.human(),
                ev.flows_completed.to_string(),
                format!("{speedup:.2}x"),
            ]);
        }
        let mut s = t.markdown();
        s.push_str(&format!(
            "\ndefault plan {} = {} | {} ranked, {} pruned, {} failed\n",
            self.baseline.candidate.key(),
            self.baseline.iteration_time.human(),
            self.ranked.len(),
            self.pruned.len(),
            self.failed.len(),
        ));
        for p in &self.pruned {
            let sched = p.schedule.map(|k| format!("-{}", k.name())).unwrap_or_default();
            s.push_str(&format!(
                "  pruned tp{}-pp{}-dp{}{sched}: {}\n",
                p.par.tp, p.par.pp, p.par.dp, p.reason
            ));
        }
        for (c, e) in &self.failed {
            s.push_str(&format!("  failed {}: {e}\n", c.key()));
        }
        s
    }
}

/// Score one candidate with a full simulated iteration.
fn evaluate(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    cand: &PlanCandidate,
    opts: &PlanOptions,
) -> anyhow::Result<EvaluatedPlan> {
    let sim = SimulationBuilder::new(model.clone(), cluster.clone())
        .parallelism(cand.par)
        .ring_policy(cand.ring)
        .hetero_partitioning(cand.partitioning == Partitioning::HeteroAware)
        .schedule(cand.schedule)
        .record_trace(true)
        .workload_options(WorkloadOptions {
            microbatch_limit: opts.microbatch_limit,
            ..Default::default()
        })
        .build()?;
    let rep = sim.run_iteration()?;
    Ok(EvaluatedPlan {
        candidate: *cand,
        iteration_time: rep.iteration_time,
        compute_busy: rep.compute_busy,
        comm_busy: rep.comm_busy,
        flows_completed: rep.flows_completed,
        events_processed: rep.events_processed,
    })
}

/// Enumerate, evaluate concurrently, rank deterministically.
pub fn search(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    opts: &PlanOptions,
) -> anyhow::Result<PlanSearchReport> {
    let (candidates, pruned) = enumerate(model, cluster, opts.microbatch_limit);
    anyhow::ensure!(
        !candidates.is_empty(),
        "no feasible TPxPPxDP factorization for {} on {} ({} factorizations pruned)",
        model.name,
        cluster.name,
        pruned.len()
    );

    let n = candidates.len();
    let results =
        parallel_map(n, opts.threads, |i| evaluate(model, cluster, &candidates[i], opts));

    let mut ranked = Vec::with_capacity(n);
    let mut failed = Vec::new();
    for (cand, res) in candidates.iter().zip(results) {
        match res {
            Ok(ev) => ranked.push(ev),
            Err(e) => failed.push((*cand, format!("{e:#}"))),
        }
    }
    if ranked.is_empty() {
        let detail = failed
            .first()
            .map(|(c, e)| format!("{}: {e}", c.key()))
            .unwrap_or_default();
        anyhow::bail!("all {n} candidates failed to evaluate — {detail}");
    }
    ranked.sort_by(|a, b| {
        a.iteration_time
            .cmp(&b.iteration_time)
            .then_with(|| a.candidate.key().cmp(&b.candidate.key()))
    });

    // The uniform default plan is normally in the candidate set — reuse
    // its evaluation; only run it separately if it was pruned away.
    let default_cand = PlanCandidate {
        par: infer_parallelism(model, cluster)?,
        partitioning: Partitioning::Uniform,
        ring: RingPolicy::HeteroAware,
        schedule: ScheduleKind::GPipe,
    };
    let baseline = match ranked.iter().find(|ev| ev.candidate == default_cand) {
        Some(ev) => ev.clone(),
        None => evaluate(model, cluster, &default_cand, opts)?,
    };
    Ok(PlanSearchReport { ranked, pruned, failed, baseline })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny_model() -> ModelSpec {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = 16;
        m.micro_batch = 8;
        m
    }

    #[test]
    fn search_ranks_and_beats_default_on_hetero() {
        let m = tiny_model();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let opts = PlanOptions { microbatch_limit: Some(1), threads: 2 };
        let rep = search(&m, &c, &opts).unwrap();
        assert!(!rep.ranked.is_empty());
        // ranked ascending by predicted time
        for w in rep.ranked.windows(2) {
            assert!(w[0].iteration_time <= w[1].iteration_time);
        }
        // the default plan is in the candidate set, so the winner can
        // never be worse than it
        assert!(rep.best().iteration_time <= rep.baseline.iteration_time);
        assert!(rep.failed.is_empty(), "{:?}", rep.failed);
    }

    #[test]
    fn render_lists_top_plans() {
        let m = tiny_model();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let opts = PlanOptions { microbatch_limit: Some(1), threads: 2 };
        let rep = search(&m, &c, &opts).unwrap();
        let text = rep.render(5);
        assert!(text.contains("Ranked parallelism plans"));
        assert!(text.contains("vs default"));
        assert!(text.contains("default plan"));
    }
}
