//! Concurrent candidate evaluation, deterministic ranking, and the
//! optional simulator-in-the-loop refinement pass.
//!
//! Every candidate is scored by building and running one full simulated
//! iteration (workload generation → cost table → dense compile → event
//! loop). Workers pull candidates off a shared atomic counter inside
//! `std::thread::scope`; the model/cluster inputs are borrowed
//! immutably by all threads. Because each simulation is deterministic
//! and the final sort uses (iteration time, candidate key), the ranked
//! output is byte-identical no matter how many workers ran the sweep.
//!
//! With [`PlanOptions::refine_steps`] > 0 the search finishes with a
//! coordinate-descent polish ([`super::refine`]): the top
//! [`REFINE_STARTS`] ranked candidates are each materialized and
//! refined, and the best refined plan is reported. Multi-start matters
//! because coordinate descent is local — the second-ranked layout
//! sometimes refines past the first.

use crate::config::cluster::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::simulator::{infer_parallelism, EvalContext, ScoreOutcome, SimulationBuilder};
use crate::system::collective::RingPolicy;
use crate::system::fold::FoldMode;
use crate::util::par::parallel_map;
use crate::util::table::Table;
use crate::util::units::Time;
use crate::workload::aicb::WorkloadOptions;
use crate::workload::schedule::ScheduleKind;

use super::candidates::{
    enumerate, enumerate_with_memory, Partitioning, PlanCandidate, PrunedCandidate, TpLayout,
};
use super::refine::{refine_with_context, RefineOptions, RefinedPlan};

/// How many top-ranked candidates the refinement pass starts from.
pub const REFINE_STARTS: usize = 3;

/// Search knobs.
#[derive(Debug, Clone)]
pub struct PlanOptions {
    /// Cap microbatches per device group during candidate evaluation.
    /// Plan *ranking* needs relative ordering, not full-batch absolute
    /// times; `None` simulates every microbatch.
    pub microbatch_limit: Option<u64>,
    /// Worker threads (0 = one per available core).
    pub threads: usize,
    /// Accepted-move budget for the simulator-in-the-loop refinement
    /// pass over the top-ranked candidates (0 = no refinement, the
    /// pre-refinement behavior).
    pub refine_steps: u64,
    /// Symmetry folding during candidate evaluation
    /// ([`crate::system::fold`]): `Auto` folds interchangeable DP
    /// replicas so large-DP candidates score in near-constant work;
    /// results are bit-identical either way, so this is purely a
    /// throughput knob. `Off` by default.
    pub fold: FoldMode,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            microbatch_limit: Some(2),
            threads: 0,
            refine_steps: 0,
            fold: FoldMode::Off,
        }
    }
}

/// One scored candidate.
#[derive(Debug, Clone)]
pub struct EvaluatedPlan {
    /// The candidate that was simulated.
    pub candidate: PlanCandidate,
    /// Predicted iteration time (the ranking criterion).
    pub iteration_time: Time,
    /// Summed per-rank compute busy time (the compute side of the
    /// compute/comm breakdown).
    pub compute_busy: Time,
    /// Summed collective busy time.
    pub comm_busy: Time,
    /// Network flows completed in the simulated iteration.
    pub flows_completed: usize,
    /// Discrete events the simulation processed.
    pub events_processed: u64,
    /// Effective goodput (useful tokens per wall-clock second) under an
    /// MTBF-driven fault schedule. `None` until filled in by
    /// [`crate::report::goodput::annotate`] — the search itself ranks
    /// on fault-free iteration time. Under Monte-Carlo annotation this
    /// is the lower 95% confidence bound on mean goodput.
    pub goodput: Option<f64>,
    /// 95% confidence interval `(lo, hi)` on mean Monte-Carlo goodput.
    /// `None` unless [`crate::report::goodput::annotate`] ran with
    /// trajectories (the `--objective goodput-ci` path).
    pub goodput_ci: Option<(f64, f64)>,
}

/// Work accounting for a bound-guided search run ([`super::bnb`]):
/// how many candidates the admissible lower bound pruned outright, how
/// many simulations the incumbent cutoff aborted early, and how many
/// paid for a full simulated iteration. `None` on the exhaustive grid
/// path, whose rendered report must stay byte-identical to earlier
/// releases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SearchStats {
    /// Candidates that survived enumeration (the grid would simulate
    /// every one of them).
    pub candidates: usize,
    /// Candidates never simulated because their analytical lower bound
    /// already exceeded the incumbent.
    pub bound_pruned: usize,
    /// Simulations aborted mid-run when their clock passed the
    /// incumbent (partial work, excluded from the ranking).
    pub cutoff_aborted: usize,
    /// Simulations that ran to completion (ranked or failed).
    pub full_sims: usize,
}

/// The full search result.
#[derive(Debug)]
pub struct PlanSearchReport {
    /// Candidates ranked by predicted iteration time (stable key
    /// tie-break) — byte-identical across runs and worker counts.
    pub ranked: Vec<EvaluatedPlan>,
    /// Factorizations / schedules excluded before evaluation, with
    /// typed reasons.
    pub pruned: Vec<PrunedCandidate>,
    /// Candidates that failed to build or run, with the error text
    /// (kept visible rather than silently dropped).
    pub failed: Vec<(PlanCandidate, String)>,
    /// The uniform default plan ([`infer_parallelism`] + uniform
    /// mapping + hetero-aware rings) under the same options.
    pub baseline: EvaluatedPlan,
    /// The simulator-in-the-loop refinement result (present when
    /// [`PlanOptions::refine_steps`] > 0): the best plan found by
    /// coordinate descent from the top-ranked candidates. Its
    /// `refined_time` is ≤ the best ranked candidate's time by
    /// construction.
    pub refined: Option<RefinedPlan>,
    /// True when no candidate fit the device-memory model and the
    /// search fell back to enumeration with memory pruning disabled
    /// (the paper's Fig-3 illustration is such a scenario). Surfaced
    /// in the rendered report so the relaxation is never silent.
    pub memory_relaxed: bool,
    /// Bound/cutoff accounting (`Some` only for `--search bnb`).
    pub stats: Option<SearchStats>,
}

impl PlanSearchReport {
    /// The top-ranked plan.
    pub fn best(&self) -> &EvaluatedPlan {
        &self.ranked[0]
    }

    /// Enumeration-prune counts grouped by
    /// [`super::candidates::PruneReason::label`], sorted by label
    /// (deterministic render order).
    pub fn prune_counts(&self) -> Vec<(&'static str, usize)> {
        let mut counts = std::collections::BTreeMap::new();
        for p in &self.pruned {
            *counts.entry(p.reason.label()).or_insert(0usize) += 1;
        }
        counts.into_iter().collect()
    }

    /// Render the ranked table (top `limit` rows, 0 = all) plus a
    /// summary line.
    pub fn render(&self, limit: usize) -> String {
        // the goodput column only appears when an annotation pass ran,
        // so fault-free renders stay byte-identical to the pre-failure
        // layout (golden fingerprints depend on this)
        let with_goodput = self.ranked.iter().any(|ev| ev.goodput.is_some());
        let with_ci = self.ranked.iter().any(|ev| ev.goodput_ci.is_some());
        let mut cols: Vec<&str> =
            vec!["rank", "plan", "iteration", "compute-busy", "comm-busy", "flows", "vs default"];
        if with_goodput {
            cols.push("goodput tok/s");
        }
        if with_ci {
            cols.push("goodput ci95");
        }
        let mut t = Table::new("Ranked parallelism plans (one simulated iteration)", &cols);
        let base = self.baseline.iteration_time.as_secs();
        let shown =
            if limit == 0 { self.ranked.len() } else { limit.min(self.ranked.len()) };
        for (i, ev) in self.ranked[..shown].iter().enumerate() {
            let speedup = base / ev.iteration_time.as_secs();
            let mut row = vec![
                (i + 1).to_string(),
                ev.candidate.key(),
                ev.iteration_time.human(),
                ev.compute_busy.human(),
                ev.comm_busy.human(),
                ev.flows_completed.to_string(),
                format!("{speedup:.2}x"),
            ];
            if with_goodput {
                row.push(match ev.goodput {
                    Some(g) => format!("{g:.0}"),
                    None => "-".to_string(),
                });
            }
            if with_ci {
                row.push(match ev.goodput_ci {
                    Some((lo, hi)) => format!("[{lo:.0}, {hi:.0}]"),
                    None => "-".to_string(),
                });
            }
            t.row(row);
        }
        let mut s = t.markdown();
        if self.memory_relaxed {
            s.push_str(
                "\nnote: no candidate fits the device-memory model \
                 (weights + Adam state); ranked with memory pruning \
                 disabled — treat as an illustration, not a deployable plan\n",
            );
        }
        s.push_str(&format!(
            "\ndefault plan {} = {} | {} ranked, {} pruned, {} failed\n",
            self.baseline.candidate.key(),
            self.baseline.iteration_time.human(),
            self.ranked.len(),
            self.pruned.len(),
            self.failed.len(),
        ));
        // the accounting block exists only on the bound-guided path, so
        // grid renders stay byte-identical to the pre-bnb goldens
        if let Some(st) = &self.stats {
            s.push_str(&format!(
                "bound-guided: {} full sims of {} candidates | \
                 {} bound-pruned, {} cutoff-aborted\n",
                st.full_sims, st.candidates, st.bound_pruned, st.cutoff_aborted,
            ));
            let counts = self.prune_counts();
            if !counts.is_empty() {
                let parts: Vec<String> =
                    counts.iter().map(|(l, n)| format!("{l}={n}")).collect();
                s.push_str(&format!("pre-prunes: {}\n", parts.join(", ")));
            }
        }
        for p in &self.pruned {
            let sched = p.schedule.map(|k| format!("-{}", k.name())).unwrap_or_default();
            s.push_str(&format!("  pruned {}{sched}: {}\n", p.key_head(), p.reason));
        }
        for (c, e) in &self.failed {
            s.push_str(&format!("  failed {}: {e}\n", c.key()));
        }
        if let Some(r) = &self.refined {
            s.push('\n');
            s.push_str(&r.render());
            let speedup = self.baseline.iteration_time.as_secs()
                / r.refined_time.as_secs().max(f64::MIN_POSITIVE);
            s.push_str(&format!("  vs default: {speedup:.2}x\n"));
        }
        s
    }
}

/// Score one candidate with a full simulated iteration. The candidate
/// is materialized into its concrete device-group mapping first
/// ([`PlanCandidate::framework`]) — the same spec the refinement pass
/// would start from. Scoring goes through the shared [`EvalContext`]
/// (one topology + warm cost cache per search run, trace recording
/// off), so per-candidate cost is workload emission + compile + the
/// event loop — nothing candidate-independent is rebuilt.
pub(crate) fn evaluate(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    cand: &PlanCandidate,
    opts: &PlanOptions,
    ctx: &EvalContext,
) -> anyhow::Result<EvaluatedPlan> {
    match evaluate_with_cutoff(model, cluster, cand, opts, ctx, None)? {
        Some(ev) => Ok(ev),
        None => anyhow::bail!("cutoff abort with no cutoff set"),
    }
}

/// [`evaluate`] under an incumbent cutoff ([`super::bnb`]): `Ok(None)`
/// means the simulated clock passed `cutoff` and the run was abandoned
/// — the candidate is provably worse than the incumbent and must not
/// be ranked. `cutoff = None` (and any run that *completes* under a
/// finite cutoff) is bit-identical to plain evaluation.
pub(crate) fn evaluate_with_cutoff(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    cand: &PlanCandidate,
    opts: &PlanOptions,
    ctx: &EvalContext,
    cutoff: Option<Time>,
) -> anyhow::Result<Option<EvaluatedPlan>> {
    let fw = cand.framework(model, cluster)?;
    let outcome = SimulationBuilder::new(model.clone(), cluster.clone())
        .parallelism(cand.par)
        .framework(fw)
        .ring_policy(cand.ring)
        .workload_options(WorkloadOptions {
            microbatch_limit: opts.microbatch_limit,
            ..Default::default()
        })
        .fold(opts.fold)
        .score_with_cutoff(ctx, cutoff)?;
    let score = match outcome {
        ScoreOutcome::Complete(s) => s,
        ScoreOutcome::Cutoff => return Ok(None),
    };
    Ok(Some(EvaluatedPlan {
        candidate: cand.clone(),
        iteration_time: score.iteration_time,
        compute_busy: score.compute_busy,
        comm_busy: score.comm_busy,
        flows_completed: score.flows_completed,
        events_processed: score.events_processed,
        goodput: None,
        goodput_ci: None,
    }))
}

/// Enumerate with the Fig-3-style memory fallback: when *everything*
/// fell to the memory model, rank anyway with memory pruning disabled
/// (flagged in the report). Shared by the grid and [`super::bnb`]
/// drivers so both search the exact same candidate space.
pub(crate) fn enumerate_relaxed(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    opts: &PlanOptions,
) -> anyhow::Result<(Vec<PlanCandidate>, Vec<PrunedCandidate>, bool)> {
    let (mut candidates, mut pruned) = enumerate(model, cluster, opts.microbatch_limit);
    let mut memory_relaxed = false;
    if candidates.is_empty() {
        let (relaxed, relaxed_pruned) =
            enumerate_with_memory(model, cluster, opts.microbatch_limit, false);
        if !relaxed.is_empty() {
            candidates = relaxed;
            pruned = relaxed_pruned;
            memory_relaxed = true;
        }
    }
    anyhow::ensure!(
        !candidates.is_empty(),
        "no feasible TPxPPxDP factorization for {} on {} ({} factorizations pruned)",
        model.name,
        cluster.name,
        pruned.len()
    );
    Ok((candidates, pruned, memory_relaxed))
}

/// Sort `ranked` by (iteration time, candidate key) — the deterministic
/// ranking order every driver reports in.
pub(crate) fn rank(ranked: &mut [EvaluatedPlan]) {
    ranked.sort_by(|a, b| {
        a.iteration_time
            .cmp(&b.iteration_time)
            .then_with(|| a.candidate.key().cmp(&b.candidate.key()))
    });
}

/// Score the uniform default plan and optionally run the
/// simulator-in-the-loop refinement pass over the top-ranked
/// candidates — the shared tail of both search drivers.
pub(crate) fn baseline_and_refine(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    opts: &PlanOptions,
    ctx: &EvalContext,
    ranked: &[EvaluatedPlan],
) -> anyhow::Result<(EvaluatedPlan, Option<RefinedPlan>)> {
    // The uniform default plan is normally in the candidate set — reuse
    // its evaluation; only run it separately if it was pruned away (or,
    // under bnb, bound-pruned / cutoff-aborted).
    let default_cand = PlanCandidate {
        par: infer_parallelism(model, cluster)?,
        layout: TpLayout::Uniform,
        partitioning: Partitioning::Uniform,
        ring: RingPolicy::HeteroAware,
        schedule: ScheduleKind::GPipe,
    };
    let baseline = match ranked.iter().find(|ev| ev.candidate == default_cand) {
        Some(ev) => ev.clone(),
        None => evaluate(model, cluster, &default_cand, opts, ctx)?,
    };

    // Optional simulator-in-the-loop polish: refine the top-ranked
    // candidates by coordinate descent and keep the best result
    // (deterministic: fixed starts, deterministic refine, strict-<
    // winner selection with earlier start winning ties).
    let refined = if opts.refine_steps > 0 {
        let ropts = RefineOptions {
            max_steps: opts.refine_steps,
            threads: opts.threads,
            microbatch_limit: opts.microbatch_limit,
            fold: opts.fold,
        };
        // Starts: the top ranked candidates, plus the best variable-TP
        // layout if none made the cut — non-uniform layouts are exactly
        // the shapes with the most layer/batch slack to rebalance.
        let mut starts: Vec<&EvaluatedPlan> = ranked.iter().take(REFINE_STARTS).collect();
        let has_variable =
            starts.iter().any(|ev| matches!(ev.candidate.layout, TpLayout::PerNode(_)));
        if !has_variable {
            starts.extend(
                ranked.iter().find(|ev| matches!(ev.candidate.layout, TpLayout::PerNode(_))),
            );
        }
        let mut best: Option<RefinedPlan> = None;
        for ev in starts {
            let start = ev.candidate.framework(model, cluster)?;
            // the ranked evaluation already measured this spec under
            // the same conditions — seed it instead of re-simulating
            let r = refine_with_context(
                model,
                cluster,
                &start,
                ev.candidate.ring,
                Some(ev.iteration_time),
                &ropts,
                ctx,
            )?;
            let wins = match &best {
                None => true,
                Some(b) => r.refined_time < b.refined_time,
            };
            if wins {
                best = Some(r);
            }
        }
        best
    } else {
        None
    };
    Ok((baseline, refined))
}

/// Enumerate, evaluate concurrently, rank deterministically.
pub fn search(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    opts: &PlanOptions,
) -> anyhow::Result<PlanSearchReport> {
    let (candidates, pruned, memory_relaxed) = enumerate_relaxed(model, cluster, opts)?;

    // Everything candidate-independent — topology, evaluated cost
    // entries, compiled cores and scores of revisited specs — is built
    // once here and shared by every worker for the rest of the run
    // (ranking, baseline and refinement).
    let ctx = EvalContext::new(model, cluster)?;
    let n = candidates.len();
    let results =
        parallel_map(n, opts.threads, |i| evaluate(model, cluster, &candidates[i], opts, &ctx));

    let mut ranked = Vec::with_capacity(n);
    let mut failed = Vec::new();
    for (cand, res) in candidates.iter().zip(results) {
        match res {
            Ok(ev) => ranked.push(ev),
            Err(e) => failed.push((cand.clone(), format!("{e:#}"))),
        }
    }
    if ranked.is_empty() {
        let detail = failed
            .first()
            .map(|(c, e)| format!("{}: {e}", c.key()))
            .unwrap_or_default();
        anyhow::bail!("all {n} candidates failed to evaluate — {detail}");
    }
    rank(&mut ranked);

    let (baseline, refined) = baseline_and_refine(model, cluster, opts, &ctx, &ranked)?;
    Ok(PlanSearchReport {
        ranked,
        pruned,
        failed,
        baseline,
        refined,
        memory_relaxed,
        stats: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny_model() -> ModelSpec {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = 16;
        m.micro_batch = 8;
        m
    }

    #[test]
    fn search_ranks_and_beats_default_on_hetero() {
        let m = tiny_model();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let opts = PlanOptions { microbatch_limit: Some(1), threads: 2, ..Default::default() };
        let rep = search(&m, &c, &opts).unwrap();
        assert!(!rep.ranked.is_empty());
        // ranked ascending by predicted time
        for w in rep.ranked.windows(2) {
            assert!(w[0].iteration_time <= w[1].iteration_time);
        }
        // the default plan is in the candidate set, so the winner can
        // never be worse than it
        assert!(rep.best().iteration_time <= rep.baseline.iteration_time);
        assert!(rep.failed.is_empty(), "{:?}", rep.failed);
    }

    #[test]
    fn refine_pass_never_regresses_on_the_best_ranked_plan() {
        let m = tiny_model();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let opts =
            PlanOptions { microbatch_limit: Some(1), threads: 2, refine_steps: 2, ..Default::default() };
        let rep = search(&m, &c, &opts).unwrap();
        let r = rep.refined.as_ref().expect("refine_steps > 0 produces a refined plan");
        // starts include the best ranked candidate, so the winner can
        // never be worse than it
        assert!(r.refined_time <= rep.best().iteration_time);
        assert!(r.refined_time <= r.initial_time);
        let text = rep.render(3);
        assert!(text.contains("refinement:"), "{text}");
        assert!(text.contains("plan: DG0["), "{text}");
    }

    #[test]
    fn render_lists_top_plans() {
        let m = tiny_model();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let opts = PlanOptions { microbatch_limit: Some(1), threads: 2, ..Default::default() };
        let rep = search(&m, &c, &opts).unwrap();
        let text = rep.render(5);
        assert!(text.contains("Ranked parallelism plans"));
        assert!(text.contains("vs default"));
        assert!(text.contains("default plan"));
    }
}
