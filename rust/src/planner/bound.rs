//! Admissible analytical lower bound on a plan candidate's simulated
//! iteration time (DESIGN.md §29).
//!
//! The branch-and-bound driver ([`super::bnb`]) orders candidates by
//! this bound and prunes every candidate whose bound already exceeds
//! the incumbent — so the bound must **never** exceed the full
//! simulated iteration time, on any cluster, fabric or schedule
//! (admissibility). The derivation is a per-rank sequential-timeline
//! argument over the exact op streams `workload/aicb.rs` emits:
//!
//! * **Compute floor.** Every rank of a pipeline stage executes, per
//!   microbatch, `num_layers` attention + MLP/MoE blocks forward and
//!   backward, sequentially, with durations drawn from the same
//!   [`CostTable`] the simulator uses — per-op times here are
//!   bit-identical to the simulated durations. Embedding, the "other"
//!   fraction, p2p transfers and every launch gap are *omitted*, which
//!   only lowers the bound. Two consequences, both true under GPipe,
//!   1F1B and interleaved 1F1B:
//!   - *bottleneck*: the iteration is at least any single rank's summed
//!     op time — `m × stage_work` for the slowest rank of any stage;
//!   - *chain*: microbatch 0 traverses every (virtual) stage forward
//!     and backward through blocking stage-boundary receives, so the
//!     iteration is at least the sum over stages of one microbatch's
//!     stage work (taking each stage's *fastest* rank keeps the chain
//!     inside a real dependency path for every TP slot).
//!
//! * **Communication floor.** Collectives are blocking: a member rank
//!   cannot pass the op before the collective's sequential flow steps
//!   all complete, and each step moves its chunk at no more than the
//!   single best link bandwidth in the topology (a flow's max-min rate
//!   is bottlenecked by *some* route link, and every link's capacity is
//!   ≤ the fabric-wide maximum — this is what makes the floor valid on
//!   rail, switch and oversubscribed leaf/spine fabrics alike). The
//!   per-collective floor replays the exact step/chunk structure of
//!   [`crate::system::collective`]'s expansion (ring: `2(n−1)` steps of
//!   `bytes/n`; RS/AG: `n−1` steps; hierarchical: the two intra-node
//!   phases, with the inter-node phase conservatively dropped) at that
//!   best-case bandwidth, with all fixed per-hop delays dropped. EP
//!   all-to-alls and resharding traffic are omitted entirely.
//!
//! A relative haircut of [`COMM_SLACK`] absorbs picosecond-level
//! rounding between the floor's closed-form f64 arithmetic and the
//! engine's integer-picosecond event times; compute terms need no
//! haircut because they are summed as the very same integer-picosecond
//! [`Time`] values the event loop schedules.
//! `tests/properties.rs::prop_bnb_bound_is_admissible` enforces
//! admissibility over random clusters × fabrics × schedules.

use crate::compute::cost::LayerWork;
use crate::compute::table::CostTable;
use crate::config::cluster::{ClusterSpec, GpuSpec};
use crate::config::framework::FrameworkSpec;
use crate::config::model::{LayerKind, ModelSpec};
use crate::network::topology::Topology;
use crate::system::collective::{select_allreduce_algo, CollectiveAlgo};
use crate::system::resharding::group_needs_resharding;
use crate::system::DeviceGroups;
use crate::util::units::{Time, PS_PER_S};
use crate::workload::aicb::stage_grad_bytes;

/// Relative haircut on the communication floor: the closed-form floor
/// is computed in f64 seconds while the engine schedules integer
/// picoseconds, so shave one part in 10⁶ to keep the floor strictly on
/// the admissible side of any rounding. (At the millisecond scales of
/// one iteration this is nanoseconds — irrelevant to pruning power.)
pub const COMM_SLACK: f64 = 1.0 - 1e-6;

/// Convert a communication floor in seconds to [`Time`], rounding
/// *down* — `Time::from_secs` rounds to nearest, which could lift a
/// floor half a picosecond above the true value.
fn comm_time(secs: f64) -> Time {
    Time::from_ps((secs * PS_PER_S as f64).floor() as u64)
}

/// Reusable lower-bound evaluator: one warm [`CostTable`] (per-op
/// times bit-identical to the simulator's) plus the fabric-wide
/// best-case link bandwidth, shared across every candidate of a
/// branch-and-bound run.
pub struct Bounder {
    table: CostTable,
    /// Max over all topology links of bytes/sec — an upper bound on any
    /// flow's max-min rate on this fabric.
    bw_best: f64,
}

impl Bounder {
    /// Build a bounder for one cluster/topology (the same [`Topology`]
    /// the evaluation context simulates on, so the link set — and
    /// therefore the best-case bandwidth — matches exactly).
    pub fn new(topology: &Topology) -> Bounder {
        let bw_best = topology
            .links
            .iter()
            .map(|l| l.bw.bytes_per_sec())
            .fold(0.0_f64, f64::max)
            .max(1.0);
        Bounder { table: CostTable::native(), bw_best }
    }

    /// The admissible lower bound (in exact simulated time units) for
    /// one materialized candidate under the given microbatch cap — the
    /// same cap the evaluation will simulate with.
    pub fn bound(
        &mut self,
        model: &ModelSpec,
        cluster: &ClusterSpec,
        fw: &FrameworkSpec,
        microbatch_limit: Option<u64>,
    ) -> anyhow::Result<Time> {
        let mlp_kind = if model.moe.is_some() { LayerKind::Moe } else { LayerKind::Mlp };
        let (n_experts, top_k) = match model.moe {
            Some(m) => (m.num_experts as f64, m.top_k as f64),
            None => (0.0, 0.0),
        };
        let work = |kind: LayerKind, mbs: u64, tp: u32, bwd: bool| LayerWork {
            kind,
            hidden: model.hidden_size as f64,
            ffn: model.ffn_hidden as f64,
            heads: model.num_heads as f64,
            seq: model.seq_len as f64,
            mbs: mbs as f64,
            n_experts,
            top_k,
            tp: tp as f64,
            is_bwd: bwd,
        };

        // register every (work, gpu) pair the floor needs, then batch-
        // evaluate once — the table dedupes against prior candidates
        for g in &fw.groups {
            let mbs = g.micro_batch.min(g.batch_share);
            for s in &g.stages {
                let tp = s.tp();
                for &r in &s.ranks {
                    let gpu = gpu_of(cluster, r)?;
                    for bwd in [false, true] {
                        self.table.register(&work(LayerKind::Attention, mbs, tp, bwd), gpu);
                        self.table.register(&work(mlp_kind, mbs, tp, bwd), gpu);
                    }
                }
            }
        }
        self.table.evaluate()?;

        // DP gradient-sync floor per stage index: 2 ring collectives
        // (RS + AG) of grad_bytes/tp over the dp participants — exactly
        // the slot-wise rings the generator emits; groups that need
        // resharding first get no floor (conservative).
        let groups = DeviceGroups::derive(fw);
        let mut dp_floor: Vec<f64> = Vec::new();
        for sync in &groups.dp_sync {
            let si = sync.stage as usize;
            if dp_floor.len() <= si {
                dp_floor.resize(si + 1, 0.0);
            }
            if group_needs_resharding(&sync.participants) {
                continue;
            }
            let n = sync.participants.len() as u64;
            let tp = sync.participants[0].tp;
            let sample =
                &fw.groups.iter().find(|g| g.stages.len() > si).unwrap().stages[si];
            let bytes =
                stage_grad_bytes(model, sample.num_layers, sample.has_embedding) / tp as u64;
            let chunk = (bytes / n).max(1) as f64;
            dp_floor[si] = 2.0 * (n - 1) as f64 * chunk / self.bw_best;
        }

        let mut bound = Time::ZERO;
        for g in &fw.groups {
            let mbs = g.micro_batch.min(g.batch_share);
            let mut m = g.num_microbatches();
            if let Some(limit) = microbatch_limit {
                m = m.min(limit.max(1));
            }
            let act_bytes = mbs * model.seq_len * model.hidden_size * model.dtype_bytes;
            // single-microbatch chain through every stage of the group
            let mut chain = Time::ZERO;
            for (si, s) in g.stages.iter().enumerate() {
                let tp = s.tp();
                let nl = s.num_layers as u64;
                // per-microbatch compute per rank (fwd + bwd attention
                // and MLP blocks), on the rank's own GPU
                let mut fastest = Time::MAX;
                let mut slowest = Time::ZERO;
                for &r in &s.ranks {
                    let gpu = gpu_of(cluster, r)?;
                    let mut t = Time::ZERO;
                    for bwd in [false, true] {
                        t = t + self.table.time(&work(LayerKind::Attention, mbs, tp, bwd), gpu)?;
                        t = t + self.table.time(&work(mlp_kind, mbs, tp, bwd), gpu)?;
                    }
                    // exact: the simulated stream contains nl ops of
                    // each of these durations, summed in integer ps
                    let t = Time::from_ps(t.as_ps() * nl);
                    fastest = fastest.min(t);
                    slowest = slowest.max(t);
                }
                if s.ranks.is_empty() {
                    fastest = Time::ZERO;
                }
                // per-microbatch TP allreduce floor: 2 per layer per
                // direction, with the algorithm the compiler would pick
                let comm_mb = if tp > 1 {
                    let per_ar = allreduce_floor(cluster, &s.ranks, act_bytes, self.bw_best);
                    4.0 * nl as f64 * per_ar
                } else {
                    0.0
                };
                let dp = dp_floor.get(si).copied().unwrap_or(0.0);
                // bottleneck: the slowest rank of this stage pays its
                // full m microbatches plus the gradient sync
                let rank_floor = Time::from_ps(slowest.as_ps() * m)
                    + comm_time(COMM_SLACK * (m as f64 * comm_mb + dp));
                bound = bound.max(rank_floor);
                chain = chain + fastest + comm_time(COMM_SLACK * comm_mb);
            }
            bound = bound.max(chain);
        }
        Ok(bound)
    }
}

fn gpu_of(cluster: &ClusterSpec, rank: u32) -> anyhow::Result<&GpuSpec> {
    cluster
        .gpu_of_rank(rank)
        .ok_or_else(|| anyhow::anyhow!("rank {rank} outside cluster {}", cluster.name))
}

/// Floor (seconds) on one TP allreduce over `ranks`: the sequential
/// step/chunk structure of the algorithm
/// [`select_allreduce_algo`] would choose, at best-case bandwidth.
fn allreduce_floor(cluster: &ClusterSpec, ranks: &[u32], bytes: u64, bw_best: f64) -> f64 {
    let n = ranks.len() as u64;
    if n < 2 {
        return 0.0;
    }
    match select_allreduce_algo(cluster, ranks) {
        CollectiveAlgo::AllReduceHierarchical => {
            // regular multi-node group (guaranteed by selection): the
            // two intra-node phases move `local−1` chunks of
            // `bytes/local` each; the inter-node phase is dropped
            // (conservative — it only adds time)
            let mut per_node: std::collections::BTreeMap<u32, u64> =
                std::collections::BTreeMap::new();
            for r in ranks {
                *per_node.entry(cluster.node_of_rank(*r).unwrap_or(u32::MAX)).or_insert(0) += 1;
            }
            let local = per_node.values().next().copied().unwrap_or(1).max(1);
            let chunk = (bytes / local).max(1) as f64;
            2.0 * (local - 1) as f64 * chunk / bw_best
        }
        // flat ring: 2(n−1) sequential steps of bytes/n
        _ => {
            let chunk = (bytes / n).max(1) as f64;
            2.0 * (n - 1) as f64 * chunk / bw_best
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::framework::ParallelismSpec;
    use crate::config::presets;
    use crate::planner::candidates::enumerate;
    use crate::simulator::{EvalContext, SimulationBuilder};
    use crate::workload::aicb::WorkloadOptions;

    fn tiny_model() -> crate::config::model::ModelSpec {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = 16;
        m.micro_batch = 8;
        m
    }

    #[test]
    fn bound_is_positive_and_below_simulated_time_on_hetero() {
        let m = tiny_model();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let ctx = EvalContext::new(&m, &c).unwrap();
        let mut b = Bounder::new(&ctx.topology());
        let (cands, _) = enumerate(&m, &c, Some(1));
        assert!(!cands.is_empty());
        for cand in &cands {
            let fw = cand.framework(&m, &c).unwrap();
            let lb = b.bound(&m, &c, &fw, Some(1)).unwrap();
            assert!(lb > Time::ZERO, "{}", cand.key());
            let score = SimulationBuilder::new(m.clone(), c.clone())
                .parallelism(cand.par)
                .framework(fw)
                .ring_policy(cand.ring)
                .workload_options(WorkloadOptions {
                    microbatch_limit: Some(1),
                    ..Default::default()
                })
                .score_with_context(&ctx)
                .unwrap();
            assert!(
                lb <= score.iteration_time,
                "{}: bound {} > simulated {}",
                cand.key(),
                lb.human(),
                score.iteration_time.human()
            );
        }
    }

    #[test]
    fn bound_scales_with_microbatches() {
        let m = tiny_model();
        let c = presets::cluster("hopper", 1).unwrap();
        let fw = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 4, pp: 1, dp: 2 }).unwrap();
        let ctx = EvalContext::new(&m, &c).unwrap();
        let mut b = Bounder::new(&ctx.topology());
        let one = b.bound(&m, &c, &fw, Some(1)).unwrap();
        let two = b.bound(&m, &c, &fw, Some(2)).unwrap();
        assert!(two > one, "more microbatches must raise the floor");
    }
}
