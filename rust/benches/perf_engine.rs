//! Perf bench (EXPERIMENTS.md §Perf): L3 hot-path throughput —
//! event-queue ops/s, flow-simulator rebalance rate, end-to-end
//! simulated-events/s, and a head-to-head of the seed's HashMap-keyed
//! scheduler (inlined below as `seed_sched`) against the dense
//! `Vec`-indexed scheduler that replaced it.
//!
//!     cargo bench --bench perf_engine

use std::time::Instant;

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::engine::{Engine, EventQueue};
use hetsim::network::flow::{FlowId, FlowSim, FlowSpec};
use hetsim::network::topology::Topology;
use hetsim::simulator::SimulationBuilder;
use hetsim::util::rng::Rng;
use hetsim::util::units::Time;
use hetsim::workload::aicb::WorkloadOptions;

#[derive(Debug, Clone, Copy)]
struct Done(FlowId);

fn bench_event_queue() {
    let n: u64 = 2_000_000;
    let mut rng = Rng::new(7);
    let mut q: EventQueue<u64> = EventQueue::with_capacity(n as usize);
    let t0 = Instant::now();
    for i in 0..n {
        q.push(Time(rng.range_u64(0, 1 << 40)), i);
    }
    while q.pop().is_some() {}
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "event queue:   {:>10.0} push+pop/s  ({n} events in {dt:.3}s)",
        2.0 * n as f64 / dt
    );
}

/// Reschedule churn: the flow simulator's cancel+re-push pattern. The
/// slab must absorb it with O(1) cancels and a footprint bounded by
/// the live window (the seed's HashSet grew with every cancel of an
/// already-fired id).
fn bench_queue_reschedule() {
    let window = 1024usize;
    let rounds: u64 = 1_000_000;
    let mut rng = Rng::new(13);
    let mut q: EventQueue<u64> = EventQueue::with_capacity(window);
    let mut ids: Vec<_> =
        (0..window as u64).map(|i| q.push(Time(rng.range_u64(0, 1 << 30)), i)).collect();
    let t0 = Instant::now();
    for i in 0..rounds {
        let k = rng.range_u64(0, window as u64) as usize;
        q.cancel(ids[k]);
        ids[k] = q.push(Time(rng.range_u64(0, 1 << 30)), i);
        // drain stale envelopes periodically so the heap stays bounded
        if q.len_approx() > 4 * window {
            while q.len_approx() > window && q.pop().is_some() {}
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "queue resched: {:>10.0} cancel+push/s ({rounds} rounds, slab {} slots in {dt:.3}s)",
        rounds as f64 / dt,
        q.slab_len()
    );
}

fn bench_flow_sim() {
    let cluster = presets::cluster_hetero(2, 2).unwrap();
    let topo = Topology::build(&cluster).unwrap();
    let total = topo.total_gpus();
    let mut fs = FlowSim::new(topo);
    fs.keep_records = false;
    let mut eng: Engine<Done> = Engine::new();
    let mut rng = Rng::new(11);
    let n = 20_000usize;
    // waves of 64 concurrent flows
    let t0 = Instant::now();
    let mut started = 0usize;
    let specs: Vec<FlowSpec> = (0..64)
        .map(|i| FlowSpec {
            src: rng.range_u64(0, total as u64) as u32,
            dst: rng.range_u64(0, total as u64) as u32,
            bytes: rng.range_u64(1 << 10, 1 << 20),
            tag: i,
        })
        .collect();
    fs.start_many(&mut eng, &specs, &Done);
    started += specs.len();
    while let Some(ev) = eng.step() {
        if fs.on_complete(&mut eng, ev.payload.0, ev.id, &Done).is_some() && started < n {
            let spec = FlowSpec {
                src: rng.range_u64(0, total as u64) as u32,
                dst: rng.range_u64(0, total as u64) as u32,
                bytes: rng.range_u64(1 << 10, 1 << 20),
                tag: started as u64,
            };
            fs.start(&mut eng, spec, &Done);
            started += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "flow sim:      {:>10.0} flows/s     ({started} flows, {} rebalances in {dt:.3}s)",
        started as f64 / dt,
        fs.rebalance_count()
    );
}

fn bench_end_to_end() {
    let model = presets::model("gpt-6.7b").unwrap();
    let cluster = presets::cluster_hetero(1, 1).unwrap();
    let sim = SimulationBuilder::new(model, cluster)
        .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
        .workload_options(WorkloadOptions { microbatch_limit: Some(2), ..Default::default() })
        .build()
        .unwrap();
    let t0 = Instant::now();
    let rep = sim.run_iteration().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "end-to-end:    {:>10.0} events/s    ({} events, {} flows in {dt:.3}s)",
        rep.events_processed as f64 / dt,
        rep.events_processed,
        rep.flows_completed
    );
}

/// Seed HashMap-state vs dense Vec-state scheduler on one prepared
/// scenario. Both must process the same event stream; the dense
/// scheduler additionally amortizes workload lowering across runs.
fn bench_scheduler_state() {
    let model = presets::model("gpt-6.7b").unwrap();
    let cluster = presets::cluster_hetero(1, 1).unwrap();
    let sim = SimulationBuilder::new(model, cluster)
        .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
        .workload_options(WorkloadOptions { microbatch_limit: Some(2), ..Default::default() })
        .build()
        .unwrap();
    let runs = 5usize;

    let t0 = Instant::now();
    let mut legacy = seed_sched::run(&sim.workload, &sim.cluster, &sim.cost).unwrap();
    for _ in 1..runs {
        legacy = seed_sched::run(&sim.workload, &sim.cluster, &sim.cost).unwrap();
    }
    let dt_legacy = t0.elapsed().as_secs_f64() / runs as f64;

    let t0 = Instant::now();
    let mut dense = sim.run_iteration().unwrap();
    for _ in 1..runs {
        dense = sim.run_iteration().unwrap();
    }
    let dt_dense = t0.elapsed().as_secs_f64() / runs as f64;

    println!(
        "sched (seed):  {:>10.0} events/s    ({} events, {} flows in {dt_legacy:.3}s)",
        legacy.events as f64 / dt_legacy,
        legacy.events,
        legacy.flows
    );
    println!(
        "sched (dense): {:>10.0} events/s    ({} events in {dt_dense:.3}s)  speedup {:.2}x",
        dense.events_processed as f64 / dt_dense,
        dense.events_processed,
        dt_legacy / dt_dense
    );
    if legacy.events != dense.events_processed
        || (legacy.iteration_secs - dense.iteration_time.as_secs()).abs() > 1e-9
    {
        println!(
            "WARNING: timelines diverged (seed {} ev / {:.6}s vs dense {} ev / {:.6}s)",
            legacy.events,
            legacy.iteration_secs,
            dense.events_processed,
            dense.iteration_time.as_secs()
        );
    }
}

fn main() {
    println!("=== L3 perf: hot-path throughput (1 core) ===");
    bench_event_queue();
    bench_queue_reschedule();
    bench_flow_sim();
    bench_end_to_end();
    bench_scheduler_state();
}

/// The seed scheduler, kept verbatim-in-spirit as the bench baseline:
/// every per-rank / per-collective / per-message lookup goes through a
/// `HashMap`, programs are re-walked and collectives re-planned on
/// every run. Retired from the library by the dense-state refactor.
mod seed_sched {
    use std::collections::HashMap;

    use hetsim::compute::table::CostTable;
    use hetsim::config::cluster::ClusterSpec;
    use hetsim::engine::Engine;
    use hetsim::network::flow::{FlowId, FlowSim, FlowSpec};
    use hetsim::network::topology::Topology;
    use hetsim::system::collective::{CollectiveExec, RingPolicy};
    use hetsim::util::units::Time;
    use hetsim::workload::op::{Op, Workload};

    const MSG_TAG_BASE: u64 = 1 << 62;

    #[derive(Debug, Clone, Copy)]
    enum SimEvent {
        ComputeDone { rank: u32 },
        FlowDone(FlowId),
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    enum RankState {
        Ready,
        Computing,
        BlockedCollective(u64),
        BlockedRecv(u64),
        Finished,
    }

    #[derive(Debug)]
    struct CollState {
        arrived: usize,
        expected: usize,
        exec: Option<CollectiveExec>,
        start: Time,
        arrivals: HashMap<u32, Time>,
    }

    #[derive(Debug, Default)]
    struct MsgState {
        delivered: bool,
        waiting: Option<u32>,
    }

    pub struct LegacyReport {
        pub iteration_secs: f64,
        pub events: u64,
        pub flows: usize,
    }

    struct Sched<'a> {
        workload: &'a Workload,
        cluster: &'a ClusterSpec,
        cost: &'a CostTable,
        flows: FlowSim,
        prog_idx: HashMap<u32, usize>,
        pc: HashMap<u32, usize>,
        state: HashMap<u32, RankState>,
        colls: HashMap<u64, CollState>,
        msgs: HashMap<u64, MsgState>,
    }

    pub fn run(
        workload: &Workload,
        cluster: &ClusterSpec,
        cost: &CostTable,
    ) -> anyhow::Result<LegacyReport> {
        let topo = Topology::build(cluster)?;
        let mut colls = HashMap::new();
        for def in &workload.collectives {
            colls.insert(
                def.id,
                CollState {
                    arrived: 0,
                    expected: def.ranks.len(),
                    exec: None,
                    start: Time::ZERO,
                    arrivals: HashMap::new(),
                },
            );
        }
        let mut s = Sched {
            workload,
            cluster,
            cost,
            flows: FlowSim::new(topo),
            prog_idx: workload.programs.iter().enumerate().map(|(i, p)| (p.rank, i)).collect(),
            pc: workload.programs.iter().map(|p| (p.rank, 0)).collect(),
            state: workload.programs.iter().map(|p| (p.rank, RankState::Ready)).collect(),
            colls,
            msgs: HashMap::new(),
        };
        let mut eng: Engine<SimEvent> = Engine::new();
        eng.max_events = 500_000_000;
        let ranks: Vec<u32> = s.workload.programs.iter().map(|p| p.rank).collect();
        for r in &ranks {
            s.advance(&mut eng, *r)?;
        }
        while let Some(ev) = eng.step() {
            match ev.payload {
                SimEvent::ComputeDone { rank } => {
                    *s.pc.get_mut(&rank).unwrap() += 1;
                    s.state.insert(rank, RankState::Ready);
                    s.advance(&mut eng, rank)?;
                }
                SimEvent::FlowDone(fid) => {
                    let rec = s.flows.on_complete(&mut eng, fid, ev.id, &SimEvent::FlowDone);
                    if let Some(rec) = rec {
                        s.on_flow_done(&mut eng, rec.tag)?;
                    }
                }
            }
        }
        let stuck = s.state.values().filter(|st| **st != RankState::Finished).count();
        anyhow::ensure!(stuck == 0, "legacy run deadlocked: {stuck} ranks unfinished");
        Ok(LegacyReport {
            iteration_secs: eng.now().as_secs(),
            events: eng.processed(),
            flows: s.flows.records.len(),
        })
    }

    impl<'a> Sched<'a> {
        fn advance(&mut self, eng: &mut Engine<SimEvent>, rank: u32) -> anyhow::Result<()> {
            let prog = &self.workload.programs[*self
                .prog_idx
                .get(&rank)
                .ok_or_else(|| anyhow::anyhow!("no program for rank {rank}"))?];
            loop {
                let pc = self.pc[&rank];
                if pc >= prog.ops.len() {
                    self.state.insert(rank, RankState::Finished);
                    return Ok(());
                }
                match &prog.ops[pc] {
                    Op::Compute { work, .. } => {
                        let gpu = self
                            .cluster
                            .gpu_of_rank(rank)
                            .ok_or_else(|| anyhow::anyhow!("rank {rank} outside cluster"))?;
                        let dur = self.cost.time(work, gpu)?;
                        eng.schedule_in(dur, SimEvent::ComputeDone { rank });
                        self.state.insert(rank, RankState::Computing);
                        return Ok(());
                    }
                    Op::Collective { def_id } => {
                        let def_id = *def_id;
                        self.state.insert(rank, RankState::BlockedCollective(def_id));
                        let ready = {
                            let now = eng.now();
                            let st = self
                                .colls
                                .get_mut(&def_id)
                                .ok_or_else(|| anyhow::anyhow!("unknown collective {def_id}"))?;
                            st.arrived += 1;
                            st.arrivals.insert(rank, now);
                            st.arrived == st.expected
                        };
                        if ready {
                            self.launch_collective(eng, def_id)?;
                        }
                        return Ok(());
                    }
                    Op::Send { peer, bytes, msg } => {
                        let tag = MSG_TAG_BASE + msg;
                        self.msgs.entry(*msg).or_default();
                        self.flows.start(
                            eng,
                            FlowSpec { src: rank, dst: *peer, bytes: *bytes, tag },
                            &SimEvent::FlowDone,
                        );
                        *self.pc.get_mut(&rank).unwrap() += 1;
                    }
                    Op::Recv { msg } => {
                        let st = self.msgs.entry(*msg).or_default();
                        if st.delivered {
                            *self.pc.get_mut(&rank).unwrap() += 1;
                        } else {
                            st.waiting = Some(rank);
                            self.state.insert(rank, RankState::BlockedRecv(*msg));
                            return Ok(());
                        }
                    }
                }
            }
        }

        fn launch_collective(
            &mut self,
            eng: &mut Engine<SimEvent>,
            def_id: u64,
        ) -> anyhow::Result<()> {
            let def = self
                .workload
                .collective(def_id)
                .ok_or_else(|| anyhow::anyhow!("unknown collective {def_id}"))?;
            let mut exec = CollectiveExec::plan(self.cluster, def, RingPolicy::HeteroAware);
            let start = eng.now();
            if exec.is_done() {
                self.finish_collective(eng, def_id)?;
                return Ok(());
            }
            let step: Vec<FlowSpec> = exec.next_step().unwrap().to_vec();
            let posted: Vec<Time> = {
                let st = &self.colls[&def_id];
                step.iter().map(|f| st.arrivals.get(&f.src).copied().unwrap_or(start)).collect()
            };
            self.flows.start_many_posted(eng, &step, Some(&posted), &SimEvent::FlowDone);
            let st = self.colls.get_mut(&def_id).unwrap();
            st.exec = Some(exec);
            st.start = start;
            Ok(())
        }

        fn on_flow_done(&mut self, eng: &mut Engine<SimEvent>, tag: u64) -> anyhow::Result<()> {
            if tag >= MSG_TAG_BASE {
                let msg = tag - MSG_TAG_BASE;
                let st = self.msgs.entry(msg).or_default();
                st.delivered = true;
                if let Some(rank) = st.waiting.take() {
                    *self.pc.get_mut(&rank).unwrap() += 1;
                    self.state.insert(rank, RankState::Ready);
                    self.advance(eng, rank)?;
                }
                return Ok(());
            }
            let (step_finished, next): (bool, Option<Vec<FlowSpec>>) = {
                let st = self
                    .colls
                    .get_mut(&tag)
                    .ok_or_else(|| anyhow::anyhow!("flow for unknown collective {tag}"))?;
                let exec = st
                    .exec
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("collective {tag} not launched"))?;
                if exec.flow_done() {
                    let next = exec.next_step().map(|s| s.to_vec());
                    (true, next)
                } else {
                    (false, None)
                }
            };
            if step_finished {
                match next {
                    Some(step) => {
                        let posted: Vec<Time> = {
                            let st = &self.colls[&tag];
                            step.iter()
                                .map(|f| st.arrivals.get(&f.src).copied().unwrap_or(st.start))
                                .collect()
                        };
                        self.flows.start_many_posted(eng, &step, Some(&posted), &SimEvent::FlowDone);
                    }
                    None => self.finish_collective(eng, tag)?,
                }
            }
            Ok(())
        }

        fn finish_collective(
            &mut self,
            eng: &mut Engine<SimEvent>,
            def_id: u64,
        ) -> anyhow::Result<()> {
            let def = self.workload.collective(def_id).unwrap();
            for r in def.ranks.clone() {
                if self.state.get(&r) == Some(&RankState::BlockedCollective(def_id)) {
                    *self.pc.get_mut(&r).unwrap() += 1;
                    self.state.insert(r, RankState::Ready);
                    self.advance(eng, r)?;
                }
            }
            Ok(())
        }
    }
}
