//! Perf bench (EXPERIMENTS.md §Perf): L3 hot-path throughput —
//! event-queue ops/s, flow-simulator rebalance rate, and end-to-end
//! simulated-events/s on a representative workload.
//!
//!     cargo bench --bench perf_engine

use std::time::Instant;

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::engine::{Engine, EventQueue};
use hetsim::network::flow::{FlowId, FlowSim, FlowSpec};
use hetsim::network::topology::Topology;
use hetsim::simulator::SimulationBuilder;
use hetsim::util::rng::Rng;
use hetsim::util::units::Time;
use hetsim::workload::aicb::WorkloadOptions;

#[derive(Debug, Clone, Copy)]
struct Done(FlowId);

fn bench_event_queue() {
    let n: u64 = 2_000_000;
    let mut rng = Rng::new(7);
    let mut q: EventQueue<u64> = EventQueue::with_capacity(n as usize);
    let t0 = Instant::now();
    for i in 0..n {
        q.push(Time(rng.range_u64(0, 1 << 40)), i);
    }
    while q.pop().is_some() {}
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "event queue:   {:>10.0} push+pop/s  ({n} events in {dt:.3}s)",
        2.0 * n as f64 / dt
    );
}

fn bench_flow_sim() {
    let cluster = presets::cluster_hetero(2, 2).unwrap();
    let topo = Topology::build(&cluster).unwrap();
    let total = topo.total_gpus();
    let mut fs = FlowSim::new(topo);
    fs.keep_records = false;
    let mut eng: Engine<Done> = Engine::new();
    let mut rng = Rng::new(11);
    let n = 20_000usize;
    // waves of 64 concurrent flows
    let t0 = Instant::now();
    let mut started = 0usize;
    let specs: Vec<FlowSpec> = (0..64)
        .map(|i| FlowSpec {
            src: rng.range_u64(0, total as u64) as u32,
            dst: rng.range_u64(0, total as u64) as u32,
            bytes: rng.range_u64(1 << 10, 1 << 20),
            tag: i,
        })
        .collect();
    fs.start_many(&mut eng, &specs, &Done);
    started += specs.len();
    while let Some(ev) = eng.step() {
        if fs.on_complete(&mut eng, ev.payload.0, ev.id, &Done).is_some() && started < n {
            let spec = FlowSpec {
                src: rng.range_u64(0, total as u64) as u32,
                dst: rng.range_u64(0, total as u64) as u32,
                bytes: rng.range_u64(1 << 10, 1 << 20),
                tag: started as u64,
            };
            fs.start(&mut eng, spec, &Done);
            started += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "flow sim:      {:>10.0} flows/s     ({started} flows, {} rebalances in {dt:.3}s)",
        started as f64 / dt,
        fs.rebalance_count()
    );
}

fn bench_end_to_end() {
    let model = presets::model("gpt-6.7b").unwrap();
    let cluster = presets::cluster_hetero(1, 1).unwrap();
    let sim = SimulationBuilder::new(model, cluster)
        .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
        .workload_options(WorkloadOptions { microbatch_limit: Some(2), ..Default::default() })
        .build()
        .unwrap();
    let t0 = Instant::now();
    let rep = sim.run_iteration().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "end-to-end:    {:>10.0} events/s    ({} events, {} flows in {dt:.3}s)",
        rep.events_processed as f64 / dt,
        rep.events_processed,
        rep.flows_completed
    );
}

fn main() {
    println!("=== L3 perf: hot-path throughput (1 core) ===");
    bench_event_queue();
    bench_flow_sim();
    bench_end_to_end();
}
