//! Ablation bench (DESIGN.md §3): what each heterogeneity-aware design
//! choice buys on a mixed A100+H100 cluster —
//! (a) uniform vs non-uniform workload partitioning (C1), full
//!     iteration, no microbatch cap (the cap would mask batch shares);
//! (b) naive vs hetero-aware logical ring ordering (C3) on an
//!     interleaved inter-node allreduce (contiguous layouts are already
//!     node-major, so the effect shows on scattered rank sets — e.g.
//!     after elastic rescheduling).
//!
//!     cargo bench --bench ablation_partition

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::engine::Engine;
use hetsim::network::flow::{FlowId, FlowSim};
use hetsim::network::topology::Topology;
use hetsim::simulator::SimulationBuilder;
use hetsim::system::collective::{
    CollectiveAlgo, CollectiveDef, CollectiveExec, CommKind, RingPolicy,
};
use hetsim::util::table::Table;

#[derive(Debug, Clone, Copy)]
struct Done(FlowId);

fn run_collective(
    cluster: &hetsim::config::cluster::ClusterSpec,
    def: &CollectiveDef,
    policy: RingPolicy,
) -> anyhow::Result<f64> {
    let topo = Topology::build(cluster)?;
    let mut fs = FlowSim::new(topo);
    let mut eng: Engine<Done> = Engine::new();
    let mut exec = CollectiveExec::plan(cluster, def, policy);
    if let Some(step) = exec.next_step().map(|s| s.to_vec()) {
        fs.start_many(&mut eng, &step, &Done);
    }
    while let Some(ev) = eng.step() {
        if fs.on_complete(&mut eng, ev.payload.0, ev.id, &Done).is_some() && exec.flow_done() {
            if let Some(next) = exec.next_step().map(|s| s.to_vec()) {
                fs.start_many(&mut eng, &next, &Done);
            }
        }
    }
    Ok(eng.now().as_secs())
}

fn main() -> anyhow::Result<()> {
    // ---- (a) partitioning policy, full iteration ----
    println!("=== Ablation (a): C1 non-uniform partitioning (GPT-6.7B, 1+1 hetero nodes) ===\n");
    let mut model = presets::model("gpt-6.7b")?;
    model.global_batch = 64; // full batch simulated (8 microbatches of 8)
    let cluster = presets::cluster_hetero(1, 1)?;
    let par = ParallelismSpec { tp: 8, pp: 1, dp: 2 };

    let mut t = Table::new(
        "(a) Iteration time by partitioning policy (no microbatch cap)",
        &["partitioning", "batch shares", "iteration", "vs uniform"],
    );
    let mut baseline = None;
    for (label, hetero_part) in [("uniform", false), ("non-uniform (C1)", true)] {
        let sim = SimulationBuilder::new(model.clone(), cluster.clone())
            .parallelism(par)
            .hetero_partitioning(hetero_part)
            .build()?;
        let shares: Vec<String> =
            sim.framework.groups.iter().map(|g| g.batch_share.to_string()).collect();
        let rep = sim.run_iteration()?;
        let secs = rep.iteration_time.as_secs();
        let base = *baseline.get_or_insert(secs);
        t.row(vec![
            label.into(),
            shares.join("/"),
            rep.iteration_time.human(),
            format!("{:+.1}%", (secs / base - 1.0) * 100.0),
        ]);
    }
    print!("{}", t.markdown());

    // ---- (b) ring ordering policy ----
    println!("\n=== Ablation (b): C3 ring graph generation (interleaved 32-rank allreduce) ===\n");
    let c4 = presets::cluster_hetero(2, 2)?;
    // interleaved rank set: strides across the 4 nodes
    let ranks: Vec<u32> = (0..32).map(|i| (i % 4) * 8 + i / 4).collect();
    let def = CollectiveDef {
        id: 0,
        algo: CollectiveAlgo::AllReduceRing,
        ranks,
        bytes_per_rank: 256 << 20,
        kind: CommKind::Dp,
        label: "ablate".into(),
    };
    let mut t2 = Table::new(
        "(b) 256 MiB allreduce, 32 interleaved ranks over 2 A100 + 2 H100 nodes",
        &["ring order", "time", "vs naive"],
    );
    let naive = run_collective(&c4, &def, RingPolicy::Naive)?;
    let aware = run_collective(&c4, &def, RingPolicy::HeteroAware)?;
    t2.row(vec!["naive".into(), format!("{:.3} ms", naive * 1e3), "+0.0%".into()]);
    t2.row(vec![
        "hetero-aware (C3)".into(),
        format!("{:.3} ms", aware * 1e3),
        format!("{:+.2}%", (aware / naive - 1.0) * 100.0),
    ]);
    print!("{}", t2.markdown());
    println!(
        "\nfinding: the rail-only fabric (one NIC per GPU per rail) absorbs bad ring\n\
         orderings almost entirely under fluid max-min sharing — C3's gain here is\n\
         latency-level only. C3 matters for correctness (vendor-agnostic graph\n\
         generation) more than for bandwidth on this topology."
    );

    let dir = hetsim::report::results_dir();
    t.write_csv(&dir, "ablation_partition")?;
    t2.write_csv(&dir, "ablation_ring_order")?;
    Ok(())
}
