//! Bench: regenerate paper **Figure 6** — FCT distribution (CCDF) of
//! all collective flows in one iteration across Ampere, Hopper and
//! Ampere+Hopper(50:50) interconnect configurations, for all three
//! Table-6 models.
//!
//! Scaled knobs (printed, never silent): HETSIM_FIG6_NODES (default 4;
//! paper 16-32) and one microbatch per group.
//!
//!     cargo bench --bench fig6

use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let nodes: u32 = std::env::var("HETSIM_FIG6_NODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    println!("=== Figure 6 — FCT CCDF across interconnect configs ===");
    println!("nodes={nodes} (paper: 16-32), microbatch_limit=1 — scaled for 1-core CI\n");

    let t0 = Instant::now();
    let cells =
        hetsim::report::fig6::compute(nodes, Some(1), &["gpt-6.7b", "gpt-13b", "mixtral-8x7b"])?;
    let dt = t0.elapsed();
    let t = hetsim::report::fig6::render(&cells);
    print!("{}", t.markdown());
    println!("\npaper reference (hetero p99.9 vs Ampere): GPT-6.7B +9%, GPT-13B 25.3x, Mixtral +0.4%");
    println!("simulation wall time: {:.2}s", dt.as_secs_f64());
    let dir = hetsim::report::results_dir();
    let path = t.write_csv(&dir, "fig6")?;
    std::fs::write(dir.join("fig6_ccdf.csv"), hetsim::report::fig6::ccdf_csv(&cells))?;
    println!("csv: {} + fig6_ccdf.csv", path.display());
    Ok(())
}
