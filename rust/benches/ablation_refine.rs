//! Refinement ablation (DESIGN.md §22): what each planning stage buys
//! on heterogeneous clusters —
//!
//! * **uniform** — the homogeneous-assumption default plan
//!   (equal layers, equal batch shares);
//! * **hetero-heuristic** — the closed-form proportional partitioner
//!   (`plan_hetero`, component C1);
//! * **searched** — the best plan from the full candidate sweep
//!   (grid factorizations + variable per-group TP layouts);
//! * **refined** — the searched winner polished by simulator-in-the-
//!   loop coordinate descent (`hetsim plan --refine`).
//!
//! Run on the paper's Fig-3 cluster (1×4×H100 + 1×4×A100, Llama-2 70B,
//! full batch — batch-share moves are invisible under a microbatch cap)
//! and the `hetero:1,1` cluster (8×A100 + 8×H100, GPT-6.7B, capped at 2
//! microbatches: layer-split refinement only). The Fig-3 rows also
//! print the hand-written `fig3_plan` reference the refiner must match
//! or beat.
//!
//!     cargo bench --bench ablation_refine

use hetsim::config::cluster::ClusterSpec;
use hetsim::config::model::ModelSpec;
use hetsim::config::presets;
use hetsim::planner::{search, PlanOptions};
use hetsim::simulator::SimulationBuilder;
use hetsim::util::table::Table;
use hetsim::util::units::Time;
use hetsim::workload::aicb::WorkloadOptions;
use hetsim::workload::partition::{fig3_cluster, fig3_model, fig3_plan};

fn simulate_spec(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    fw: hetsim::config::framework::FrameworkSpec,
    mb_limit: Option<u64>,
) -> anyhow::Result<Time> {
    let sim = SimulationBuilder::new(model.clone(), cluster.clone())
        .parallelism(fw.base)
        .framework(fw)
        .workload_options(WorkloadOptions { microbatch_limit: mb_limit, ..Default::default() })
        .build()?;
    Ok(sim.run_iteration()?.iteration_time)
}

fn ablate(
    t: &mut Table,
    label: &str,
    model: &ModelSpec,
    cluster: &ClusterSpec,
    mb_limit: Option<u64>,
    reference: Option<Time>,
) -> anyhow::Result<()> {
    let opts = PlanOptions { microbatch_limit: mb_limit, threads: 0, refine_steps: 64, ..Default::default() };
    let report = search(model, cluster, &opts)?;
    let refined = report.refined.as_ref().expect("refine_steps > 0");
    let base = report.baseline.iteration_time.as_secs();
    let mut row = |stage: &str, time: Time, plan: String| {
        t.row(vec![
            label.into(),
            stage.into(),
            time.human(),
            format!("{:.2}x", base / time.as_secs()),
            plan,
        ]);
    };
    row("uniform default", report.baseline.iteration_time, report.baseline.candidate.key());
    // best closed-form hetero-heuristic candidate in the ranked set
    if let Some(h) = report
        .ranked
        .iter()
        .filter(|ev| {
            ev.candidate.partitioning == hetsim::planner::Partitioning::HeteroAware
                && ev.candidate.layout == hetsim::planner::TpLayout::Uniform
        })
        .min_by_key(|ev| ev.iteration_time)
    {
        row("hetero-heuristic", h.iteration_time, h.candidate.key());
    }
    row("searched", report.best().iteration_time, report.best().candidate.key());
    row("refined", refined.refined_time, refined.spec.summary());
    if let Some(r) = reference {
        row("fig3_plan (hand-written)", r, "paper Fig 3".into());
    }
    println!(
        "{label}: {} moves accepted, {} evaluations",
        refined.moves.len(),
        refined.evaluations
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("=== Ablation: uniform → hetero-heuristic → searched → refined ===\n");
    let mut t = Table::new(
        "Iteration time by planning stage",
        &["scenario", "stage", "iteration", "vs uniform", "plan"],
    );

    // (a) the paper's Fig-3 scenario, full batch, with the hand-written
    // reference
    let m = fig3_model()?;
    let c = fig3_cluster()?;
    let reference = simulate_spec(&m, &c, fig3_plan(&m, &c)?, None)?;
    ablate(&mut t, "fig3 (Llama-2 70B)", &m, &c, None, Some(reference))?;

    // (b) the hetero 1+1 preset (`hetsim plan --cluster hetero:1,1`),
    // capped at 2 microbatches like the CLI default
    let m = presets::model("gpt-6.7b")?;
    let c = presets::cluster_hetero(1, 1)?;
    ablate(&mut t, "hetero:1,1 (GPT-6.7B)", &m, &c, Some(2), None)?;

    print!("\n{}", t.markdown());
    Ok(())
}
