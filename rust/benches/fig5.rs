//! Bench: regenerate paper **Figure 5** — per-layer compute time for
//! GPT-6.7B / GPT-13B / Mixtral-8x7B on H100 vs A100, through BOTH cost
//! backends (native mirror and the PJRT-executed AOT artifact), timing
//! each.
//!
//!     make artifacts && cargo bench --bench fig5

use std::time::Instant;

use hetsim::compute::table::CostTable;

fn run(label: &str, mut table: CostTable) -> anyhow::Result<()> {
    let t0 = Instant::now();
    let rows = hetsim::report::fig5::compute(&mut table)?;
    let dt = t0.elapsed();
    let t = hetsim::report::fig5::render(&rows);
    println!("--- backend: {label} ({:.1} ms) ---", dt.as_secs_f64() * 1e3);
    print!("{}", t.markdown());
    println!();
    let dir = hetsim::report::results_dir();
    t.write_csv(&dir, &format!("fig5_{label}"))?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("=== Figure 5 — per-layer compute time across GPU generations ===");
    println!("paper reference: MLP 3-4x, attention <=1.9x, embedding ~36.1x (A100/H100)\n");
    run("native", CostTable::native())?;
    match hetsim::runtime::PjrtCostModel::load() {
        Ok(m) => run("pjrt", CostTable::new(Box::new(m)))?,
        Err(e) => println!("[skipped pjrt backend: {e}]"),
    }
    Ok(())
}
