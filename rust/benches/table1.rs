//! Bench: regenerate paper **Table 1** — exposed-communication
//! characteristics of DP/TP/PP for Llama-2 70B on 2048 GPUs
//! (TP=8 PP=8 DP=32). Also times the 2048-rank workload generation.
//!
//!     cargo bench --bench table1

use std::time::Instant;

fn main() -> anyhow::Result<()> {
    println!("=== Table 1 (Llama-2 70B, 2048 GPUs, TP8/PP8/DP32) ===\n");
    let t0 = Instant::now();
    let rows = hetsim::report::table1::compute()?;
    let gen = t0.elapsed();
    let t = hetsim::report::table1::render(&rows);
    print!("{}", t.markdown());
    println!("\npaper reference: DP 2/iter @ 4.4GB; TP 350/iter @ small; PP 8/iter @ small");
    println!("workload generation + analysis: {:.2}s (2048 ranks)", gen.as_secs_f64());
    let dir = hetsim::report::results_dir();
    let path = t.write_csv(&dir, "table1")?;
    println!("csv: {}", path.display());
    Ok(())
}
