//! Ablation bench: component-level costs the paper's Table 3/4 imply —
//! (a) resharding overhead (C2): Fig-3 non-uniform-TP plan vs uniform
//!     TP on identical hardware;
//! (b) collective algorithm choice (C3): flat ring vs hierarchical
//!     (rail-aware) DP allreduce across nodes.
//!
//!     cargo bench --bench ablation_components

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::engine::Engine;
use hetsim::network::flow::{FlowId, FlowSim};
use hetsim::network::topology::Topology;
use hetsim::simulator::SimulationBuilder;
use hetsim::system::collective::{
    CollectiveAlgo, CollectiveDef, CollectiveExec, CommKind, RingPolicy,
};
use hetsim::util::table::Table;
use hetsim::workload::partition::{fig3_cluster, fig3_model, fig3_plan};

#[derive(Debug, Clone, Copy)]
struct Done(FlowId);

fn run_collective(
    cluster: &hetsim::config::cluster::ClusterSpec,
    def: &CollectiveDef,
) -> anyhow::Result<f64> {
    let topo = Topology::build(cluster)?;
    let mut fs = FlowSim::new(topo);
    let mut eng: Engine<Done> = Engine::new();
    let mut exec = CollectiveExec::plan(cluster, def, RingPolicy::HeteroAware);
    if let Some(step) = exec.next_step().map(|s| s.to_vec()) {
        fs.start_many(&mut eng, &step, &Done);
    }
    while let Some(ev) = eng.step() {
        if fs.on_complete(&mut eng, ev.payload.0, ev.id, &Done).is_some() && exec.flow_done() {
            if let Some(next) = exec.next_step().map(|s| s.to_vec()) {
                fs.start_many(&mut eng, &next, &Done);
            }
        }
    }
    Ok(eng.now().as_secs())
}

fn main() -> anyhow::Result<()> {
    println!("=== Ablation: resharding (C2) and collective algorithm (C3) ===\n");

    // (a) resharding overhead: Fig-3 plan vs uniform TP=4
    let model = fig3_model()?;
    let cluster = fig3_cluster()?;
    let fig3 = SimulationBuilder::new(model.clone(), cluster.clone())
        .framework(fig3_plan(&model, &cluster)?)
        .build()?;
    let reshard_colls =
        fig3.workload.collectives.iter().filter(|c| c.kind == CommKind::Reshard).count();
    let fig3_rep = fig3.run_iteration()?;
    let uniform_rep = SimulationBuilder::new(model, cluster.clone())
        .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 2 })
        .build()?
        .run_iteration()?;

    let mut t = Table::new(
        "(a) Resharding: Fig-3 variable-TP plan vs uniform TP (Llama-2 70B, 4xH100+4xA100)",
        &["plan", "reshard collectives", "iteration"],
    );
    t.row(vec![
        "fig3 variable TP (3/1 vs 4)".into(),
        reshard_colls.to_string(),
        fig3_rep.iteration_time.human(),
    ]);
    t.row(vec!["uniform TP=4".into(), "0".into(), uniform_rep.iteration_time.human()]);
    print!("{}", t.markdown());

    // (b) flat ring vs hierarchical allreduce across 4 nodes
    let c = presets::cluster("hopper", 4)?;
    let bytes = 256u64 << 20;
    let mut t2 = Table::new(
        "(b) DP allreduce algorithm, 32 ranks over 4 nodes, 256 MiB/rank",
        &["algorithm", "time"],
    );
    for (label, algo) in [
        ("flat ring", CollectiveAlgo::AllReduceRing),
        ("hierarchical (rail-aware)", CollectiveAlgo::AllReduceHierarchical),
    ] {
        let def = CollectiveDef {
            id: 0,
            algo,
            ranks: (0..32).collect(),
            bytes_per_rank: bytes,
            kind: CommKind::Dp,
            label: label.into(),
        };
        let secs = run_collective(&c, &def)?;
        t2.row(vec![label.into(), format!("{:.3} ms", secs * 1e3)]);
    }
    println!();
    print!("{}", t2.markdown());
    let dir = hetsim::report::results_dir();
    t.write_csv(&dir, "ablation_resharding")?;
    t2.write_csv(&dir, "ablation_collective_algo")?;
    Ok(())
}
