//! Schedule ablation: what the pipeline schedule buys on a mixed
//! A100+H100 pipeline — GPipe (seed behavior, microbatch-sequential)
//! vs 1F1B vs interleaved 1F1B, same model, same partitioning, same
//! rings. Reports simulated iteration time, the compute/comm busy
//! breakdown and the bubble reduction vs GPipe.
//!
//!     cargo bench -p hetsim --bench ablation_schedule

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::simulator::SimulationBuilder;
use hetsim::util::table::Table;
use hetsim::workload::schedule::ScheduleKind;

fn main() -> anyhow::Result<()> {
    println!("=== Schedule ablation: GPT-6.7B pipeline on 1+1 hetero nodes ===\n");
    let mut model = presets::model("gpt-6.7b")?;
    model.num_layers = 8;
    model.global_batch = 64;
    model.micro_batch = 2; // 16 microbatches per group: deep pipeline ramp
    let cluster = presets::cluster_hetero(1, 1)?;
    let par = ParallelismSpec { tp: 4, pp: 2, dp: 2 };

    let mut t = Table::new(
        "Iteration time by pipeline schedule (tp4-pp2-dp2, 16 microbatches)",
        &["schedule", "iteration", "compute-busy", "comm-busy", "vs gpipe"],
    );
    let mut baseline = None;
    for schedule in [
        ScheduleKind::GPipe,
        ScheduleKind::OneFOneB,
        ScheduleKind::Interleaved1F1B { vpp: 2 },
        ScheduleKind::Interleaved1F1B { vpp: 4 },
    ] {
        let wall = std::time::Instant::now();
        let rep = SimulationBuilder::new(model.clone(), cluster.clone())
            .parallelism(par)
            .schedule(schedule)
            .record_trace(true)
            .build()?
            .run_iteration()?;
        let secs = rep.iteration_time.as_secs();
        let base = *baseline.get_or_insert(secs);
        t.row(vec![
            schedule.name(),
            rep.iteration_time.human(),
            rep.compute_busy.human(),
            rep.comm_busy.human(),
            format!("{:+.1}%", (secs / base - 1.0) * 100.0),
        ]);
        eprintln!(
            "  [{}] {} events, {} flows, {:.2}s wall",
            schedule.name(),
            rep.events_processed,
            rep.flows_completed,
            wall.elapsed().as_secs_f64()
        );
    }
    print!("{}", t.markdown());
    println!(
        "\nGPipe runs microbatches strictly sequentially (the seed behavior); the \
         pipelining schedules overlap stages, so the gap above is the simulated \
         bubble time the schedule removes."
    );
    Ok(())
}
