//! Planner integration: the ranked plan list must be deterministic —
//! identical across repeated runs and across worker-thread counts —
//! and the winner must never lose to the uniform default plan that is
//! part of its own candidate set.

use hetsim::config::presets;
use hetsim::planner::{search, PlanOptions};

fn tiny_model() -> hetsim::config::model::ModelSpec {
    let mut m = presets::model("gpt-6.7b").unwrap();
    m.num_layers = 4;
    m.global_batch = 16;
    m.micro_batch = 8;
    m
}

fn ranking_fingerprint(threads: usize) -> String {
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let opts = PlanOptions { microbatch_limit: Some(1), threads };
    let rep = search(&m, &c, &opts).unwrap();
    // full rendered output: keys, times, breakdowns, prune notes
    rep.render(0)
}

#[test]
fn ranking_identical_across_runs() {
    assert_eq!(ranking_fingerprint(2), ranking_fingerprint(2));
}

#[test]
fn ranking_identical_across_thread_counts() {
    let one = ranking_fingerprint(1);
    for threads in [2, 4] {
        assert_eq!(one, ranking_fingerprint(threads), "threads={threads}");
    }
}

#[test]
fn winner_beats_or_ties_uniform_default_on_hetero_cluster() {
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let opts = PlanOptions { microbatch_limit: Some(1), threads: 4 };
    let rep = search(&m, &c, &opts).unwrap();
    assert!(rep.ranked.len() >= 8, "only {} plans ranked", rep.ranked.len());
    assert!(
        rep.best().iteration_time <= rep.baseline.iteration_time,
        "best {} > default {}",
        rep.best().iteration_time,
        rep.baseline.iteration_time
    );
    // compute/comm breakdown is populated
    assert!(rep.best().compute_busy.as_secs() > 0.0);
    assert!(rep.best().comm_busy.as_secs() > 0.0);
}
