//! Planner integration: the ranked plan list must be deterministic —
//! identical across repeated runs and across worker-thread counts —
//! and the winner must never lose to the uniform default plan that is
//! part of its own candidate set.

use hetsim::config::presets;
use hetsim::planner::{enumerate, search, PlanOptions};
use hetsim::workload::schedule::ScheduleKind;

fn tiny_model() -> hetsim::config::model::ModelSpec {
    let mut m = presets::model("gpt-6.7b").unwrap();
    m.num_layers = 4;
    m.global_batch = 16;
    m.micro_batch = 8;
    m
}

fn ranking_fingerprint(threads: usize) -> String {
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let opts = PlanOptions { microbatch_limit: Some(1), threads };
    let rep = search(&m, &c, &opts).unwrap();
    // full rendered output: keys, times, breakdowns, prune notes
    rep.render(0)
}

#[test]
fn ranking_identical_across_runs() {
    assert_eq!(ranking_fingerprint(2), ranking_fingerprint(2));
}

#[test]
fn ranking_identical_across_thread_counts() {
    let one = ranking_fingerprint(1);
    for threads in [2, 4] {
        assert_eq!(one, ranking_fingerprint(threads), "threads={threads}");
    }
}

#[test]
fn plan_crosses_all_schedule_kinds_on_hetero_preset() {
    // acceptance: `hetsim plan --model gpt-6.7b --cluster hetero:1,1`
    // (default --mb-limit 2) must enumerate GPipe, 1F1B and interleaved
    // candidates
    let m = presets::model("gpt-6.7b").unwrap();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let (keep, _) = enumerate(&m, &c, Some(2));
    for want in [
        ScheduleKind::GPipe,
        ScheduleKind::OneFOneB,
        ScheduleKind::Interleaved1F1B { vpp: 2 },
    ] {
        assert!(
            keep.iter().any(|cand| cand.schedule == want),
            "no {want} candidate among {}",
            keep.len()
        );
    }
}

#[test]
fn ranked_output_contains_every_schedule_kind() {
    // the tiny search model exposes pp in {1, 2, 4}: pp=2 carries all
    // three schedules, and every evaluated schedule must rank (none may
    // silently land in `failed`)
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let opts = PlanOptions { microbatch_limit: Some(1), threads: 2 };
    let rep = search(&m, &c, &opts).unwrap();
    assert!(rep.failed.is_empty(), "{:?}", rep.failed);
    for want in [
        ScheduleKind::GPipe,
        ScheduleKind::OneFOneB,
        ScheduleKind::Interleaved1F1B { vpp: 2 },
    ] {
        assert!(
            rep.ranked.iter().any(|ev| ev.candidate.schedule == want),
            "no ranked {want} plan"
        );
    }
}

#[test]
fn winner_beats_or_ties_uniform_default_on_hetero_cluster() {
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let opts = PlanOptions { microbatch_limit: Some(1), threads: 4 };
    let rep = search(&m, &c, &opts).unwrap();
    assert!(rep.ranked.len() >= 8, "only {} plans ranked", rep.ranked.len());
    assert!(
        rep.best().iteration_time <= rep.baseline.iteration_time,
        "best {} > default {}",
        rep.best().iteration_time,
        rep.baseline.iteration_time
    );
    // compute/comm breakdown is populated
    assert!(rep.best().compute_busy.as_secs() > 0.0);
    assert!(rep.best().comm_busy.as_secs() > 0.0);
}
