//! Planner integration: the ranked plan list must be deterministic —
//! identical across repeated runs and across worker-thread counts —
//! the winner must never lose to the uniform default plan that is part
//! of its own candidate set, and the simulator-in-the-loop refinement
//! pass must (a) never lose to the `plan_hetero` closed-form heuristic,
//! (b) match or beat the paper's hand-written Fig-3 plan, and (c) stay
//! byte-identical across 1/4/8 worker threads.

use hetsim::config::presets;
use hetsim::planner::{enumerate, search, Partitioning, PlanOptions, TpLayout};
use hetsim::simulator::SimulationBuilder;
use hetsim::workload::aicb::WorkloadOptions;
use hetsim::workload::partition::{fig3_cluster, fig3_model, fig3_plan};
use hetsim::workload::schedule::ScheduleKind;

fn tiny_model() -> hetsim::config::model::ModelSpec {
    let mut m = presets::model("gpt-6.7b").unwrap();
    m.num_layers = 4;
    m.global_batch = 16;
    m.micro_batch = 8;
    m
}

fn ranking_fingerprint(threads: usize) -> String {
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let opts = PlanOptions { microbatch_limit: Some(1), threads, refine_steps: 2, ..Default::default() };
    let rep = search(&m, &c, &opts).unwrap();
    // full rendered output: keys, times, breakdowns, prune notes
    rep.render(0)
}

#[test]
fn ranking_identical_across_runs() {
    assert_eq!(ranking_fingerprint(2), ranking_fingerprint(2));
}

#[test]
fn ranking_identical_across_thread_counts() {
    // the fingerprint includes the refinement trajectory
    // (refine_steps > 0), so this also pins the refiner's determinism
    let one = ranking_fingerprint(1);
    for threads in [2, 4, 8] {
        assert_eq!(one, ranking_fingerprint(threads), "threads={threads}");
    }
}

#[test]
fn plan_crosses_all_schedule_kinds_on_hetero_preset() {
    // acceptance: `hetsim plan --model gpt-6.7b --cluster hetero:1,1`
    // (default --mb-limit 2) must enumerate GPipe, 1F1B and interleaved
    // candidates
    let m = presets::model("gpt-6.7b").unwrap();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let (keep, _) = enumerate(&m, &c, Some(2));
    for want in [
        ScheduleKind::GPipe,
        ScheduleKind::OneFOneB,
        ScheduleKind::Interleaved1F1B { vpp: 2 },
    ] {
        assert!(
            keep.iter().any(|cand| cand.schedule == want),
            "no {want} candidate among {}",
            keep.len()
        );
    }
}

#[test]
fn ranked_output_contains_every_schedule_kind() {
    // the tiny search model exposes pp in {1, 2, 4}: pp=2 carries all
    // three schedules, and every evaluated schedule must rank (none may
    // silently land in `failed`)
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let opts = PlanOptions { microbatch_limit: Some(1), threads: 2, refine_steps: 0, ..Default::default() };
    let rep = search(&m, &c, &opts).unwrap();
    assert!(rep.failed.is_empty(), "{:?}", rep.failed);
    for want in [
        ScheduleKind::GPipe,
        ScheduleKind::OneFOneB,
        ScheduleKind::Interleaved1F1B { vpp: 2 },
    ] {
        assert!(
            rep.ranked.iter().any(|ev| ev.candidate.schedule == want),
            "no ranked {want} plan"
        );
    }
}

#[test]
fn winner_beats_or_ties_uniform_default_on_hetero_cluster() {
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let opts = PlanOptions { microbatch_limit: Some(1), threads: 4, refine_steps: 0, ..Default::default() };
    let rep = search(&m, &c, &opts).unwrap();
    assert!(rep.ranked.len() >= 8, "only {} plans ranked", rep.ranked.len());
    assert!(
        rep.best().iteration_time <= rep.baseline.iteration_time,
        "best {} > default {}",
        rep.best().iteration_time,
        rep.baseline.iteration_time
    );
    // compute/comm breakdown is populated
    assert!(rep.best().compute_busy.as_secs() > 0.0);
    assert!(rep.best().comm_busy.as_secs() > 0.0);
}

#[test]
fn refined_never_loses_to_the_hetero_heuristic_on_the_hetero_preset() {
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let opts = PlanOptions { microbatch_limit: Some(1), threads: 4, refine_steps: 8, ..Default::default() };
    let rep = search(&m, &c, &opts).unwrap();
    let refined = rep.refined.as_ref().expect("refinement requested");
    // the plan_hetero heuristic (grid layout, hetero-aware
    // partitioning) is in the ranked set; refinement starts from the
    // best ranked candidate, so it can never lose to the heuristic
    let heuristic = rep
        .ranked
        .iter()
        .filter(|ev| {
            ev.candidate.layout == TpLayout::Uniform
                && ev.candidate.partitioning == Partitioning::HeteroAware
        })
        .map(|ev| ev.iteration_time)
        .min()
        .expect("hetero-aware candidates ranked");
    assert!(
        refined.refined_time <= heuristic,
        "refined {} > plan_hetero heuristic {}",
        refined.refined_time,
        heuristic
    );
    assert!(refined.refined_time <= rep.best().iteration_time);
}

#[test]
fn fig3_refined_matches_or_beats_the_handwritten_plan() {
    // acceptance: `hetsim plan --refine --mb-limit 0` on the Fig-3
    // cluster must find a plan at least as good as the paper's
    // hand-written fig3_plan (75/5-layer split, 16/8 batch shares),
    // under identical evaluation conditions. Full batch (no microbatch
    // cap): a cap truncates every group to the same simulated
    // microbatch count, which hides exactly the batch-share effects
    // the refiner optimizes.
    let m = fig3_model().unwrap();
    let c = fig3_cluster().unwrap();
    let plan = fig3_plan(&m, &c).unwrap();
    let reference = SimulationBuilder::new(m.clone(), c.clone())
        .parallelism(plan.base)
        .framework(plan)
        .workload_options(WorkloadOptions {
            microbatch_limit: None,
            ..Default::default()
        })
        .build()
        .unwrap()
        .run_iteration()
        .unwrap()
        .iteration_time;

    let opts = PlanOptions { microbatch_limit: None, threads: 4, refine_steps: 20, ..Default::default() };
    let rep = search(&m, &c, &opts).unwrap();
    assert!(rep.memory_relaxed, "fig3 planning requires the memory-relaxed fallback");
    let refined = rep.refined.as_ref().unwrap();
    // the refiner also never loses to the plan_hetero heuristic here
    let heuristic = rep
        .ranked
        .iter()
        .filter(|ev| {
            ev.candidate.layout == TpLayout::Uniform
                && ev.candidate.partitioning == Partitioning::HeteroAware
        })
        .map(|ev| ev.iteration_time)
        .min()
        .expect("hetero-aware candidates ranked");
    assert!(refined.refined_time <= heuristic);
    assert!(
        refined.refined_time <= reference,
        "refined {} > hand-written fig3_plan {} (refined plan: {})",
        refined.refined_time,
        reference,
        refined.spec.summary()
    );
}
