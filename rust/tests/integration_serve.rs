//! End-to-end serving simulation suite (DESIGN.md §27).
//!
//! Three layers of enforcement, mirroring `golden_plan.rs`:
//!
//! 1. **Cross-thread identity** (always on): rendered serve-sim
//!    reports are byte-identical across 1/4/8 worker threads for every
//!    scheduling policy.
//! 2. **Behavioral contrasts** (always on): FIFO and SRPT order the
//!    same trace differently where queueing theory says they must, an
//!    empty trace renders an empty report without panicking, and
//!    `fold=auto` under a serving workload is bit-identical to
//!    `fold=off`.
//! 3. **Golden fingerprint** (self-bootstrapping, see
//!    `tests/golden/README.md`): the Fig-3 serve-sim report is
//!    recorded on first run and compared byte-for-byte afterwards.

use std::fs;
use std::path::PathBuf;

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::system::fold::FoldMode;
use hetsim::system::serve_scheduler::ServeSim;
use hetsim::workload::partition::{fig3_cluster, fig3_model};
use hetsim::workload::serve::{PoissonSpec, Request, ServePolicy, ServeSpec};
use hetsim::SimulationBuilder;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

/// Compare `content` against the committed golden file, or record it on
/// first run (bootstrap).
fn check_golden(name: &str, content: &str) {
    let path = golden_dir().join(name);
    if path.exists() {
        let want = fs::read_to_string(&path).unwrap();
        assert_eq!(
            want,
            content,
            "golden fingerprint {} drifted — serving changes must be deliberate. \
             If this change is intentional, delete the file and rerun to re-record.",
            path.display()
        );
    } else {
        fs::create_dir_all(golden_dir()).unwrap();
        fs::write(&path, content).unwrap();
        eprintln!(
            "recorded golden fingerprint {} — commit it to pin this behavior",
            path.display()
        );
    }
}

fn req(arrival_s: f64, prompt: u64, output: u64) -> Request {
    Request { arrival_s, prompt_tokens: prompt, output_tokens: output, weight: 1.0 }
}

fn poisson_spec(policy: ServePolicy) -> ServeSpec {
    ServeSpec {
        poisson: Some(PoissonSpec {
            rate_per_s: 4.0,
            horizon_s: 10.0,
            scale: 1.0,
            prompt_tokens: 512,
            output_tokens: 64,
        }),
        policy,
        seed: 7,
        ..Default::default()
    }
}

#[test]
fn serve_reports_thread_invariant_per_policy_on_fig3() {
    for policy in [ServePolicy::Fifo, ServePolicy::Srpt, ServePolicy::Wsrpt] {
        let sim = ServeSim::new(fig3_model().unwrap(), fig3_cluster().unwrap(), poisson_spec(policy))
            .unwrap();
        let one = sim.run(1).unwrap().render();
        for threads in [4, 8] {
            assert_eq!(
                one,
                sim.run(threads).unwrap().render(),
                "policy {} diverged at threads={threads}",
                policy.name()
            );
        }
    }
}

#[test]
fn serve_fifo_vs_srpt_order_differs_on_hetero() {
    // A long request ahead of four short ones, all at t=0 with
    // max_batch=1: FIFO must serve in arrival order, SRPT must let the
    // shorts overtake — lowering median latency and changing the
    // rendered report.
    let mut requests = vec![req(0.0, 1024, 64)];
    for _ in 0..4 {
        requests.push(req(0.0, 32, 4));
    }
    let run = |policy| {
        let spec = ServeSpec { requests: requests.clone(), policy, max_batch: 1, ..Default::default() };
        ServeSim::new(
            presets::model("gpt-6.7b").unwrap(),
            presets::cluster_hetero(1, 1).unwrap(),
            spec,
        )
        .unwrap()
        .run(1)
        .unwrap()
    };
    let fifo = run(ServePolicy::Fifo);
    let srpt = run(ServePolicy::Srpt);
    // conservation holds under both policies
    assert_eq!(fifo.requests_total, 5);
    assert_eq!(srpt.requests_total, 5);
    assert_eq!(fifo.tokens_out_total, srpt.tokens_out_total);
    // ...but the ordering (and therefore the latency profile) differs
    assert!(
        srpt.latency.p50_s < fifo.latency.p50_s,
        "SRPT p50 {} must beat FIFO p50 {}",
        srpt.latency.p50_s,
        fifo.latency.p50_s
    );
    assert_ne!(fifo.render(), srpt.render());
}

#[test]
fn serve_zero_request_trace_reports_empty() {
    // scale=0 thins every Poisson candidate away: a structurally valid
    // spec that generates nothing.
    let spec = ServeSpec {
        poisson: Some(PoissonSpec {
            rate_per_s: 4.0,
            horizon_s: 5.0,
            scale: 0.0,
            ..Default::default()
        }),
        ..Default::default()
    };
    let sim = ServeSim::new(
        presets::model("gpt-6.7b").unwrap(),
        presets::cluster_hetero(1, 1).unwrap(),
        spec,
    )
    .unwrap();
    assert!(sim.requests().is_empty());
    let rep = sim.run(1).unwrap();
    assert_eq!(rep.requests_total, 0);
    assert_eq!(rep.tokens_out_total, 0);
    assert_eq!(rep.events, 0);
    assert_eq!(rep.goodput_tok_s, 0.0);
    assert_eq!(rep.latency.count, 0);
    let text = rep.render();
    assert!(text.contains("requests 0"), "{text}");
}

#[test]
fn serve_sim_fig3_golden() {
    // The canonical serving scenario: the paper's Fig-3 cluster (one
    // 4xH100 node + one 4xA100 node) serving a seeded Poisson trace
    // under SRPT. Renders only simulated quantities, so the fingerprint
    // is machine-independent.
    let sim = ServeSim::new(
        fig3_model().unwrap(),
        fig3_cluster().unwrap(),
        poisson_spec(ServePolicy::Srpt),
    )
    .unwrap();
    let rep = sim.run(1).unwrap();
    assert!(rep.requests_total > 0);
    assert!(rep.goodput_tok_s > 0.0);
    assert!(rep.ttft.p99_s > 0.0);
    check_golden("serve_sim_fig3.txt", &rep.render());
}

#[test]
fn serve_example_scenario_runs_end_to_end() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("examples/scenario_serving.json");
    let s = hetsim::config::loader::load_scenario_file(&path).unwrap();
    let serving = s.serving.expect("example scenario carries serving traffic");
    assert_eq!(serving.policy, ServePolicy::Srpt);
    let sim = ServeSim::new(s.model, s.cluster, serving).unwrap();
    let rep = sim.run(2).unwrap();
    assert_eq!(rep.requests_total as usize, sim.requests().len());
    assert!(rep.requests_total >= 2, "pinned requests must be served");
}

#[test]
fn serve_fold_auto_stays_bit_identical_to_fold_off() {
    // The fold-interaction guard: a serving workload must veto symmetry
    // folding, leaving fold=auto builds bit-identical to fold=off for
    // both the training iteration and the serving run.
    let mut model = presets::model("gpt-6.7b").unwrap();
    model.num_layers = 4;
    model.global_batch = 16;
    model.micro_batch = 8;
    let cluster = presets::cluster("ampere", 2).unwrap();
    let serving = ServeSpec {
        requests: vec![req(0.0, 128, 8), req(0.1, 64, 4)],
        ..Default::default()
    };
    let build = |fold| {
        SimulationBuilder::new(model.clone(), cluster.clone())
            .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
            .fold(fold)
            .serving(Some(serving.clone()))
            .build()
            .unwrap()
    };
    let auto = build(FoldMode::Auto);
    let off = build(FoldMode::Off);
    assert!(!auto.folded(), "serving must refuse symmetry folding");
    assert!(!off.folded());
    let (ra, ro) = (auto.run_iteration().unwrap(), off.run_iteration().unwrap());
    assert_eq!(ra.iteration_time, ro.iteration_time);
    assert_eq!(ra.events_processed, ro.events_processed);
    assert_eq!(ra.flows_completed, ro.flows_completed);
    assert_eq!(
        auto.run_serve(1).unwrap().render(),
        off.run_serve(1).unwrap().render()
    );
    // sanity: the same deployment without serving does fold
    let folded = SimulationBuilder::new(model.clone(), cluster.clone())
        .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
        .fold(FoldMode::Auto)
        .build()
        .unwrap();
    assert!(folded.folded(), "baseline deployment should be foldable");
}
