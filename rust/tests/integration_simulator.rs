//! Full-pipeline integration: config -> workload -> cost -> scheduler
//! -> network, across cluster kinds and models.

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::simulator::SimulationBuilder;
use hetsim::util::units::Time;
use hetsim::workload::aicb::WorkloadOptions;

fn small_opts() -> WorkloadOptions {
    WorkloadOptions { microbatch_limit: Some(1), ..Default::default() }
}

#[test]
fn gpt67_one_microbatch_on_two_nodes() {
    let model = presets::model("gpt-6.7b").unwrap();
    let rep = SimulationBuilder::new(model, presets::cluster("hopper", 2).unwrap())
        .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 4 })
        .workload_options(small_opts())
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    assert!(rep.iteration_time > Time::ZERO);
    // 32 layers x 4 TP-allreduce x 4 groups collectives happened
    assert!(rep.fct_summary["TP"].count > 1000);
    assert!(rep.fct_summary["DP"].count > 0);
}

#[test]
fn pipeline_parallel_runs_and_is_slower_than_nothing() {
    let mut model = presets::model("llama2-70b").unwrap();
    model.global_batch = 8;
    model.micro_batch = 1;
    let rep = SimulationBuilder::new(model, presets::cluster("hopper", 2).unwrap())
        .parallelism(ParallelismSpec { tp: 4, pp: 2, dp: 2 })
        .workload_options(small_opts())
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    assert!(rep.fct_summary.contains_key("PP"));
    assert!(rep.iteration_time > Time::ZERO);
}

#[test]
fn moe_model_produces_ep_traffic() {
    let mut model = presets::model("mixtral-8x7b").unwrap();
    model.num_layers = 8;
    let rep = SimulationBuilder::new(model, presets::cluster("hopper", 1).unwrap())
        .parallelism(ParallelismSpec { tp: 2, pp: 1, dp: 4 })
        .workload_options(small_opts())
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    assert!(rep.fct_summary["EP"].count > 0);
}

#[test]
fn ampere_slower_than_hopper_same_workload() {
    let run = |arch: &str| {
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = 8;
        SimulationBuilder::new(model, presets::cluster(arch, 1).unwrap())
            .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 2 })
            .workload_options(small_opts())
            .build()
            .unwrap()
            .run_iteration()
            .unwrap()
            .iteration_time
    };
    let hopper = run("hopper");
    let ampere = run("ampere");
    // compute-dominated: expect roughly the fig-5 MLP factor
    let ratio = ampere.as_secs() / hopper.as_secs();
    assert!(ratio > 1.5, "ampere/hopper ratio {ratio}");
}

#[test]
fn hetero_between_the_two_homogeneous_clusters() {
    let mk = |cluster| {
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = 8;
        SimulationBuilder::new(model, cluster)
            .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
            .workload_options(small_opts())
            .build()
            .unwrap()
            .run_iteration()
            .unwrap()
            .iteration_time
    };
    let hopper = mk(presets::cluster("hopper", 2).unwrap());
    let ampere = mk(presets::cluster("ampere", 2).unwrap());
    let hetero = mk(presets::cluster_hetero(1, 1).unwrap());
    assert!(hetero >= hopper, "hetero {hetero} < hopper {hopper}");
    assert!(hetero <= ampere, "hetero {hetero} > ampere {ampere}");
}

#[test]
fn scenario_file_roundtrip() {
    let dir = std::env::temp_dir().join("hetsim_it_scenario");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("scenario.json");
    std::fs::write(
        &path,
        r#"{"model": {"name": "tiny", "num_layers": 4, "hidden_size": 1024,
                      "num_heads": 16, "ffn_hidden": 4096, "seq_len": 512,
                      "global_batch": 16, "micro_batch": 4},
            "cluster": {"arch": "hetero", "ampere_nodes": 1, "hopper_nodes": 1},
            "parallelism": {"tp": 4, "pp": 1, "dp": 4}}"#,
    )
    .unwrap();
    let s = hetsim::config::loader::load_scenario_file(&path).unwrap();
    let rep = SimulationBuilder::new(s.model, s.cluster)
        .parallelism(s.parallelism)
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    assert!(rep.iteration_time > Time::ZERO);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn workload_trace_file_drives_identical_simulation() {
    // generate -> serialize -> parse -> simulate == direct simulate
    let mut model = presets::model("gpt-6.7b").unwrap();
    model.num_layers = 4;
    model.global_batch = 8;
    model.micro_batch = 4;
    let cluster = presets::cluster("hopper", 1).unwrap();
    let fw = hetsim::config::framework::FrameworkSpec::uniform(
        &model,
        &cluster,
        ParallelismSpec { tp: 4, pp: 1, dp: 2 },
    )
    .unwrap();
    let w = hetsim::workload::aicb::generate(
        &model,
        &cluster,
        &fw,
        &WorkloadOptions::default(),
    )
    .unwrap();
    let text = hetsim::workload::parser::write(&w);
    let w2 = hetsim::workload::parser::parse(&text).unwrap();

    let mut cost = hetsim::compute::table::CostTable::native();
    hetsim::workload::aicb::register_costs(&w, &cluster, &mut cost).unwrap();
    let r1 = hetsim::system::scheduler::Scheduler::new(&w, &cluster, &cost)
        .unwrap()
        .run()
        .unwrap();
    let r2 = hetsim::system::scheduler::Scheduler::new(&w2, &cluster, &cost)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(r1.iteration_time, r2.iteration_time);
    assert_eq!(r1.flows_completed, r2.flows_completed);
}

#[test]
fn longer_training_scales_linearly_ish() {
    let run = |mb_limit| {
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = 4;
        model.global_batch = 64;
        model.micro_batch = 8;
        SimulationBuilder::new(model, presets::cluster("hopper", 1).unwrap())
            .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 2 })
            .workload_options(WorkloadOptions {
                microbatch_limit: Some(mb_limit),
                ..Default::default()
            })
            .build()
            .unwrap()
            .run_iteration()
            .unwrap()
            .iteration_time
    };
    let one = run(1);
    let four = run(4);
    let ratio = four.as_secs() / one.as_secs();
    assert!((2.0..6.0).contains(&ratio), "4 microbatches / 1 = {ratio}");
}
