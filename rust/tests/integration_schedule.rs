//! Schedule-subsystem integration: the refactored, schedule-driven
//! workload generator with `--schedule gpipe` must reproduce the
//! pre-refactor (seed) generator **bit-for-bit** — identical serialized
//! workloads (op streams, collective ids, labels, p2p tags) and
//! identical simulated timelines — and the non-GPipe schedules must
//! produce valid, deterministic, faster-or-equal pipelines.
//!
//! `seed_generate` below is the seed generator inlined verbatim (same
//! pattern as the seed scheduler kept in `benches/perf_engine.rs`), so
//! the equivalence is checked against the real historical behavior, not
//! against a re-derivation.

use hetsim::compute::table::CostTable;
use hetsim::config::framework::{FrameworkSpec, ParallelismSpec};
use hetsim::config::model::ModelSpec;
use hetsim::config::presets;
use hetsim::system::scheduler::Scheduler;
use hetsim::workload::aicb::{self, WorkloadOptions};
use hetsim::workload::parser;
use hetsim::workload::schedule::ScheduleKind;
use hetsim::workload::Workload;

/// The seed (pre-refactor) AICB generator, inlined verbatim from the
/// PR-1 tree: per microbatch, forward over all stages then backward
/// over all stages, with tags and collective ids allocated in walk
/// order.
mod seed_gen {
    use std::collections::HashMap;

    use hetsim::compute::cost::LayerWork;
    use hetsim::config::cluster::ClusterSpec;
    use hetsim::config::framework::FrameworkSpec;
    use hetsim::config::model::{LayerKind, ModelSpec};
    use hetsim::system::collective::{CollectiveAlgo, CollectiveDef, CommKind};
    use hetsim::system::device_group::DeviceGroups;
    use hetsim::system::resharding;
    use hetsim::workload::aicb::{stage_grad_bytes, WorkloadOptions};
    use hetsim::workload::op::{Op, RankProgram, Workload};

    pub fn seed_generate(
        model: &ModelSpec,
        cluster: &ClusterSpec,
        fw: &FrameworkSpec,
        opts: &WorkloadOptions,
    ) -> anyhow::Result<Workload> {
        fw.validate(model, cluster)?;
        let groups = DeviceGroups::derive(fw);
        let mut ops: HashMap<u32, Vec<Op>> = HashMap::new();
        for g in &fw.groups {
            for r in g.ranks() {
                ops.insert(r, Vec::new());
            }
        }
        let mut colls: Vec<CollectiveDef> = Vec::new();
        let mut next_coll: u64 = 0;
        let mut next_msg: u64 = 0;

        let d = model.dtype_bytes;
        let mlp_kind = if model.moe.is_some() { LayerKind::Moe } else { LayerKind::Mlp };
        let (n_experts, top_k) = match model.moe {
            Some(m) => (m.num_experts as f64, m.top_k as f64),
            None => (0.0, 0.0),
        };

        let layer_work = |kind: LayerKind, mbs: u64, tp: u32, bwd: bool| LayerWork {
            kind,
            hidden: model.hidden_size as f64,
            ffn: model.ffn_hidden as f64,
            heads: model.num_heads as f64,
            seq: model.seq_len as f64,
            mbs: mbs as f64,
            n_experts,
            top_k,
            tp: tp as f64,
            is_bwd: bwd,
        };

        for g in &fw.groups {
            let mbs = g.micro_batch.min(g.batch_share);
            let mut m = g.num_microbatches();
            if let Some(limit) = opts.microbatch_limit {
                m = m.min(limit.max(1));
            }
            let act_bytes = mbs * model.seq_len * model.hidden_size * d;

            for mb in 0..m {
                // ---------------- forward ----------------
                for (s, stage) in g.stages.iter().enumerate() {
                    let tp = stage.tp();
                    let ranks = &stage.ranks;
                    if s > 0 {
                        emit_p2p(
                            &mut ops,
                            &mut next_msg,
                            &g.stages[s - 1].ranks,
                            ranks,
                            act_bytes,
                        );
                    }
                    if stage.has_embedding {
                        for r in ranks {
                            ops.get_mut(r).unwrap().push(Op::Compute {
                                work: layer_work(LayerKind::Embedding, mbs, tp, false),
                                label: "embedding-fwd",
                            });
                        }
                    }
                    for _layer in 0..stage.num_layers {
                        for r in ranks {
                            ops.get_mut(r).unwrap().push(Op::Compute {
                                work: layer_work(LayerKind::Attention, mbs, tp, false),
                                label: "attention-fwd",
                            });
                        }
                        if tp > 1 {
                            emit_collective(
                                &mut ops,
                                &mut colls,
                                &mut next_coll,
                                CollectiveAlgo::AllReduceRing,
                                ranks.clone(),
                                act_bytes,
                                CommKind::Tp,
                                format!("tp-ar-g{}s{s}mb{mb}-attn-f", g.id),
                            );
                        }
                        if mlp_kind == LayerKind::Moe && opts.moe_alltoall && tp > 1 {
                            emit_collective(
                                &mut ops,
                                &mut colls,
                                &mut next_coll,
                                CollectiveAlgo::AllToAll,
                                ranks.clone(),
                                act_bytes * model.moe.unwrap().top_k as u64,
                                CommKind::Ep,
                                format!("ep-a2a-g{}s{s}mb{mb}-disp-f", g.id),
                            );
                        }
                        for r in ranks {
                            ops.get_mut(r).unwrap().push(Op::Compute {
                                work: layer_work(mlp_kind, mbs, tp, false),
                                label: if mlp_kind == LayerKind::Moe {
                                    "moe-fwd"
                                } else {
                                    "mlp-fwd"
                                },
                            });
                        }
                        if mlp_kind == LayerKind::Moe && opts.moe_alltoall && tp > 1 {
                            emit_collective(
                                &mut ops,
                                &mut colls,
                                &mut next_coll,
                                CollectiveAlgo::AllToAll,
                                ranks.clone(),
                                act_bytes * model.moe.unwrap().top_k as u64,
                                CommKind::Ep,
                                format!("ep-a2a-g{}s{s}mb{mb}-comb-f", g.id),
                            );
                        }
                        if tp > 1 {
                            emit_collective(
                                &mut ops,
                                &mut colls,
                                &mut next_coll,
                                CollectiveAlgo::AllReduceRing,
                                ranks.clone(),
                                act_bytes,
                                CommKind::Tp,
                                format!("tp-ar-g{}s{s}mb{mb}-mlp-f", g.id),
                            );
                        }
                        if opts.include_other {
                            for r in ranks {
                                ops.get_mut(r).unwrap().push(Op::Compute {
                                    work: layer_work(LayerKind::Other, mbs, tp, false),
                                    label: "other-fwd",
                                });
                            }
                        }
                    }
                }
                // ---------------- backward (stages reversed) ----------------
                for (s, stage) in g.stages.iter().enumerate().rev() {
                    let tp = stage.tp();
                    let ranks = &stage.ranks;
                    if s + 1 < g.stages.len() {
                        emit_p2p(
                            &mut ops,
                            &mut next_msg,
                            &g.stages[s + 1].ranks,
                            ranks,
                            act_bytes,
                        );
                    }
                    for _layer in 0..stage.num_layers {
                        for r in ranks {
                            ops.get_mut(r).unwrap().push(Op::Compute {
                                work: layer_work(mlp_kind, mbs, tp, true),
                                label: if mlp_kind == LayerKind::Moe {
                                    "moe-bwd"
                                } else {
                                    "mlp-bwd"
                                },
                            });
                        }
                        if tp > 1 {
                            emit_collective(
                                &mut ops,
                                &mut colls,
                                &mut next_coll,
                                CollectiveAlgo::AllReduceRing,
                                ranks.clone(),
                                act_bytes,
                                CommKind::Tp,
                                format!("tp-ar-g{}s{s}mb{mb}-mlp-b", g.id),
                            );
                        }
                        for r in ranks {
                            ops.get_mut(r).unwrap().push(Op::Compute {
                                work: layer_work(LayerKind::Attention, mbs, tp, true),
                                label: "attention-bwd",
                            });
                        }
                        if tp > 1 {
                            emit_collective(
                                &mut ops,
                                &mut colls,
                                &mut next_coll,
                                CollectiveAlgo::AllReduceRing,
                                ranks.clone(),
                                act_bytes,
                                CommKind::Tp,
                                format!("tp-ar-g{}s{s}mb{mb}-attn-b", g.id),
                            );
                        }
                    }
                    if stage.has_embedding {
                        for r in ranks {
                            ops.get_mut(r).unwrap().push(Op::Compute {
                                work: layer_work(LayerKind::Embedding, mbs, tp, true),
                                label: "embedding-bwd",
                            });
                        }
                    }
                }
            }
        }

        if opts.dp_sync {
            for sync in &groups.dp_sync {
                let stage_idx = sync.stage as usize;
                let sample = &fw
                    .groups
                    .iter()
                    .find(|g| g.stages.len() > stage_idx)
                    .unwrap()
                    .stages[stage_idx];
                let full_bytes = stage_grad_bytes(model, sample.num_layers, sample.has_embedding);
                if resharding::group_needs_resharding(&sync.participants) {
                    let plan = resharding::plan(
                        &sync.participants,
                        full_bytes,
                        sync.stage,
                        &mut next_coll,
                    );
                    for def in plan.all_defs() {
                        colls.push(def.clone());
                        for r in &def.ranks {
                            ops.get_mut(r).unwrap().push(Op::Collective { def_id: def.id });
                        }
                    }
                } else {
                    let tp = sync.participants[0].tp;
                    for slot in 0..tp as usize {
                        let ranks: Vec<u32> =
                            sync.participants.iter().map(|p| p.ranks[slot]).collect();
                        for (algo, tag) in [
                            (CollectiveAlgo::ReduceScatter, "rs"),
                            (CollectiveAlgo::AllGather, "ag"),
                        ] {
                            let id = next_coll;
                            next_coll += 1;
                            let def = CollectiveDef {
                                id,
                                algo,
                                ranks: ranks.clone(),
                                bytes_per_rank: full_bytes / tp as u64,
                                kind: CommKind::Dp,
                                label: format!("dp-{tag}-s{}slot{slot}", sync.stage),
                            };
                            colls.push(def);
                            for r in &ranks {
                                ops.get_mut(r).unwrap().push(Op::Collective { def_id: id });
                            }
                        }
                    }
                }
            }
        }

        let mut programs: Vec<RankProgram> = ops
            .into_iter()
            .map(|(rank, ops)| RankProgram { rank, ops })
            .collect();
        programs.sort_by_key(|p| p.rank);
        let w = Workload { programs, collectives: colls };
        w.validate()?;
        Ok(w)
    }

    fn emit_p2p(
        ops: &mut HashMap<u32, Vec<Op>>,
        next_msg: &mut u64,
        from: &[u32],
        to: &[u32],
        act_bytes: u64,
    ) {
        if from.len() == to.len() {
            let per = (act_bytes / from.len() as u64).max(1);
            for (s, r) in from.iter().zip(to.iter()) {
                let msg = *next_msg;
                *next_msg += 1;
                ops.get_mut(s).unwrap().push(Op::Send { peer: *r, bytes: per, msg });
                ops.get_mut(r).unwrap().push(Op::Recv { msg });
            }
        } else {
            let leader = from[0];
            for r in to {
                let msg = *next_msg;
                *next_msg += 1;
                ops.get_mut(&leader)
                    .unwrap()
                    .push(Op::Send { peer: *r, bytes: act_bytes, msg });
                ops.get_mut(r).unwrap().push(Op::Recv { msg });
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn emit_collective(
        ops: &mut HashMap<u32, Vec<Op>>,
        colls: &mut Vec<CollectiveDef>,
        next_coll: &mut u64,
        algo: CollectiveAlgo,
        ranks: Vec<u32>,
        bytes_per_rank: u64,
        kind: CommKind,
        label: String,
    ) {
        let id = *next_coll;
        *next_coll += 1;
        for r in &ranks {
            ops.get_mut(r).unwrap().push(Op::Collective { def_id: id });
        }
        colls.push(CollectiveDef { id, algo, ranks, bytes_per_rank, kind, label });
    }
}

fn tiny_model() -> ModelSpec {
    let mut m = presets::model("gpt-6.7b").unwrap();
    m.num_layers = 4;
    m.global_batch = 16;
    m.micro_batch = 4;
    m
}

/// Run a workload through the (lazily compiling) scheduler and return
/// the report.
fn simulate(
    w: &Workload,
    cluster: &hetsim::config::cluster::ClusterSpec,
) -> hetsim::system::scheduler::SchedulerReport {
    let mut cost = CostTable::native();
    aicb::register_costs(w, cluster, &mut cost).unwrap();
    Scheduler::new(w, cluster, &cost).unwrap().run().unwrap()
}

/// New generator under `--schedule gpipe` vs the inlined seed
/// generator: serialized traces must be byte-identical and the
/// simulated timelines bit-for-bit equal.
fn assert_gpipe_matches_seed(
    model: &ModelSpec,
    cluster: &hetsim::config::cluster::ClusterSpec,
    fw: &FrameworkSpec,
    opts: &WorkloadOptions,
) {
    assert_eq!(fw.schedule, ScheduleKind::GPipe, "test wants the default schedule");
    let seed = seed_gen::seed_generate(model, cluster, fw, opts).unwrap();
    let new = aicb::generate(model, cluster, fw, opts).unwrap();
    assert_eq!(
        parser::write(&seed),
        parser::write(&new),
        "serialized workloads differ"
    );
    let seed_rep = simulate(&seed, cluster);
    let new_rep = simulate(&new, cluster);
    assert_eq!(seed_rep.iteration_time, new_rep.iteration_time);
    assert_eq!(seed_rep.flows_completed, new_rep.flows_completed);
    assert_eq!(seed_rep.events_processed, new_rep.events_processed);
}

#[test]
fn gpipe_bit_identical_homogeneous_pipeline() {
    let m = tiny_model();
    let c = presets::cluster("hopper", 1).unwrap();
    let fw = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 2, pp: 2, dp: 2 }).unwrap();
    assert_gpipe_matches_seed(&m, &c, &fw, &WorkloadOptions::default());
}

#[test]
fn gpipe_bit_identical_hetero_nonuniform_partition() {
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let fw =
        hetsim::workload::plan_hetero(&m, &c, ParallelismSpec { tp: 4, pp: 2, dp: 2 }).unwrap();
    assert_gpipe_matches_seed(&m, &c, &fw, &WorkloadOptions::default());
}

#[test]
fn gpipe_bit_identical_moe_alltoall() {
    let mut m = presets::model("mixtral-8x7b").unwrap();
    m.num_layers = 2;
    m.global_batch = 8;
    m.micro_batch = 4;
    let c = presets::cluster("hopper", 1).unwrap();
    let fw = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 2, pp: 1, dp: 4 }).unwrap();
    assert_gpipe_matches_seed(&m, &c, &fw, &WorkloadOptions::default());
}

#[test]
fn gpipe_bit_identical_fig3_resharding_plan() {
    // variable TP degrees (3 vs 1 vs 4), leader fan-out p2p, resharded
    // DP sync — the hardest emission path
    let m = hetsim::workload::partition::fig3_model().unwrap();
    let c = hetsim::workload::partition::fig3_cluster().unwrap();
    let fw = hetsim::workload::partition::fig3_plan(&m, &c).unwrap();
    // cap microbatches for CI speed; bit-identity holds under any options
    let opts = WorkloadOptions { microbatch_limit: Some(2), ..Default::default() };
    assert_gpipe_matches_seed(&m, &c, &fw, &opts);
}

#[test]
fn non_gpipe_schedules_validate_and_run_on_hetero() {
    // both pipelining schedules must produce valid workloads (generate
    // runs Workload::validate) that simulate to completion without
    // deadlock on a heterogeneous pipeline with non-uniform layers
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    for kind in [ScheduleKind::OneFOneB, ScheduleKind::Interleaved1F1B { vpp: 2 }] {
        let fw = hetsim::workload::plan_hetero(&m, &c, ParallelismSpec { tp: 4, pp: 2, dp: 2 })
            .unwrap()
            .with_schedule(kind);
        let w = aicb::generate(&m, &c, &fw, &WorkloadOptions::default()).unwrap();
        let rep = simulate(&w, &c);
        assert!(rep.iteration_time > hetsim::util::units::Time::ZERO, "{kind}");
        // run twice: deterministic
        let rep2 = simulate(&w, &c);
        assert_eq!(rep.iteration_time, rep2.iteration_time, "{kind}");
        assert_eq!(rep.events_processed, rep2.events_processed, "{kind}");
    }
}
