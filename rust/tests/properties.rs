//! Property-based tests over the simulator's core invariants, using the
//! in-tree `util::prop` harness (proptest substitute, DESIGN.md S17):
//!
//! * event-queue ordering & cancellation safety,
//! * partition conservation (layers, batch),
//! * collective-plan traffic conservation & step structure,
//! * routing validity on random topologies,
//! * max-min fairness feasibility (no link over-subscription),
//! * workload validation under random generator configs,
//! * resharding trigger conditions,
//! * layer/batch conservation under random refinement-move sequences,
//! * symmetry folding (`fold=auto`) reproduces the unfolded run's
//!   timing exactly on random clusters / fabrics / schedules,
//! * an empty fault spec is bit-identical to configuring no faults,
//! * effective goodput is monotone non-increasing in the MTBF
//!   failure-rate scale (nested-thinning schedules + monotone walk),
//! * fault-aware plan sweeps are deterministic across worker-thread
//!   counts,
//! * zero-length repair windows make the degraded-mode walk
//!   bit-identical to the fail-stop baseline,
//! * a 1-trajectory Monte-Carlo run reproduces the deterministic walk,
//!   and trajectory sets are byte-identical across thread counts and
//!   nested in the trajectory count,
//! * correlated domain schedules are nested across rate scales and
//!   strike complete failure domains,
//! * seeded Poisson request traces are reproducible and nested across
//!   rate scales (same thinning construction as the MTBF schedules),
//! * serving simulation conserves requests (every admitted request
//!   completes exactly once), never exceeds any group's KV budget, and
//!   renders byte-identically across worker-thread counts,
//! * the branch-and-bound lower bound is admissible: it never exceeds
//!   the fully simulated iteration time on random clusters / fabrics /
//!   schedules (with a non-vacuity counter of strictly positive
//!   bounds),
//! * incumbent-cutoff simulation is bit-identical to plain scoring
//!   when the cutoff is absent, unreachable, or exactly equal to the
//!   final iteration time (the strict-inequality abort rule), and a
//!   cutoff strictly below the final time always aborts,
//! * `--search bnb` returns the exact grid-best plan and renders
//!   byte-identically across 1/4/8 worker threads.

use hetsim::config::framework::{FrameworkSpec, ParallelismSpec};
use hetsim::config::presets;
use hetsim::engine::EventQueue;
use hetsim::network::routing;
use hetsim::network::topology::Topology;
use hetsim::system::collective::{
    ring_order, CollectiveAlgo, CollectiveDef, CollectiveExec, CommKind, RingPolicy,
};
use hetsim::util::prop::{check, Config};
use hetsim::util::rng::Rng;
use hetsim::util::units::Time;
use hetsim::workload::partition::split_proportional;

fn cfg(cases: usize) -> Config {
    Config { cases, max_size: 48, seed: 0xDEC0DE }
}

#[test]
fn prop_event_queue_pops_sorted_with_random_cancellation() {
    check(&cfg(128), |g| {
        let mut q: EventQueue<u64> = EventQueue::new();
        let n = g.size * 4;
        let mut ids = Vec::new();
        for i in 0..n {
            let t = Time(g.rng.range_u64(0, 50));
            ids.push(q.push(t, i as u64));
        }
        // cancel a random subset
        let mut cancelled = std::collections::HashSet::new();
        for id in &ids {
            if g.rng.f64() < 0.3 {
                q.cancel(*id);
                cancelled.insert(*id);
            }
        }
        let mut last = Time::ZERO;
        let mut popped = 0;
        while let Some(ev) = q.pop() {
            if ev.time < last {
                return Err(format!("time went backwards: {} < {}", ev.time, last));
            }
            if cancelled.contains(&ev.id) {
                return Err("cancelled event popped".into());
            }
            last = ev.time;
            popped += 1;
        }
        if popped != n - cancelled.len() {
            return Err(format!("popped {popped}, expected {}", n - cancelled.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_split_proportional_conserves_and_honors_minimum() {
    check(&cfg(200), |g| {
        let parts = g.rng.range_usize(1, 12);
        let minimum = g.rng.range_u64(0, 4);
        let total = minimum * parts as u64 + g.rng.range_u64(0, 1000);
        let weights: Vec<f64> = (0..parts).map(|_| g.rng.range_f64(0.0, 10.0)).collect();
        let split = split_proportional(total, &weights, minimum)
            .map_err(|e| format!("feasible split rejected: {e}"))?;
        if split.iter().sum::<u64>() != total {
            return Err(format!("sum {} != {total}", split.iter().sum::<u64>()));
        }
        if split.iter().any(|p| *p < minimum) {
            return Err(format!("minimum violated: {split:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_collective_plans_conserve_traffic() {
    let cluster = presets::cluster_hetero(2, 2).unwrap();
    check(&cfg(96), |g| {
        let n = g.rng.range_usize(2, 17);
        let mut ranks: Vec<u32> = (0..32).collect();
        g.rng.shuffle(&mut ranks);
        ranks.truncate(n);
        let bytes = g.rng.range_u64(n as u64, 1 << 24);
        let algo = *g.rng.choose(&[
            CollectiveAlgo::AllReduceRing,
            CollectiveAlgo::AllGather,
            CollectiveAlgo::ReduceScatter,
            CollectiveAlgo::AllToAll,
        ]);
        let def = CollectiveDef {
            id: 1,
            algo,
            ranks: ranks.clone(),
            bytes_per_rank: bytes,
            kind: CommKind::Dp,
            label: "p".into(),
        };
        let exec = CollectiveExec::plan(&cluster, &def, RingPolicy::HeteroAware);
        let total = exec.total_bytes();
        let chunk = (bytes / n as u64).max(1);
        let expect = match algo {
            CollectiveAlgo::AllReduceRing => 2 * (n as u64 - 1) * n as u64 * chunk,
            CollectiveAlgo::AllGather | CollectiveAlgo::ReduceScatter => {
                (n as u64 - 1) * n as u64 * chunk
            }
            CollectiveAlgo::AllToAll => (n as u64 - 1) * n as u64 * chunk,
            _ => total,
        };
        if total != expect {
            return Err(format!("{algo:?} n={n} bytes={bytes}: {total} != {expect}"));
        }
        // every step's flows reference participating ranks only
        for step in &exec.steps {
            for f in step {
                if !ranks.contains(&f.src) || !ranks.contains(&f.dst) {
                    return Err(format!("flow outside group: {f:?}"));
                }
                if f.src == f.dst {
                    return Err("self-flow in collective".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ring_order_is_permutation_and_bounded_crossings() {
    let cluster = presets::cluster_hetero(2, 2).unwrap();
    check(&cfg(96), |g| {
        let n = g.rng.range_usize(2, 33).min(32);
        let mut ranks: Vec<u32> = (0..32).collect();
        g.rng.shuffle(&mut ranks);
        ranks.truncate(n);
        let ordered = ring_order(&cluster, &ranks, RingPolicy::HeteroAware);
        let mut a = ranks.clone();
        let mut b = ordered.clone();
        a.sort_unstable();
        b.sort_unstable();
        if a != b {
            return Err("ring order is not a permutation".into());
        }
        // at most 2 architecture crossings around the ring
        let arch = |r: u32| cluster.gpu_of_rank(r).unwrap().name.clone();
        let crossings = (0..ordered.len())
            .filter(|&i| arch(ordered[i]) != arch(ordered[(i + 1) % ordered.len()]))
            .count();
        if crossings > 2 {
            return Err(format!("{crossings} architecture crossings: {ordered:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_routes_valid_and_connected() {
    check(&cfg(64), |g| {
        let nodes = g.rng.range_u64(1, 5) as u32;
        let cluster = presets::cluster_hetero(nodes, nodes).unwrap();
        let topo = Topology::build(&cluster).unwrap();
        let total = topo.total_gpus();
        for _ in 0..16 {
            let src = g.rng.range_u64(0, total as u64) as u32;
            let dst = g.rng.range_u64(0, total as u64) as u32;
            let r = routing::route(&topo, src, dst);
            // link chain is connected: each link's head is next link's tail
            for w in r.links.windows(2) {
                let a = topo.link(w[0]).to;
                let b = topo.link(w[1]).from;
                if a != b {
                    return Err(format!("disconnected route {src}->{dst}: {a:?} != {b:?}"));
                }
            }
            if src != dst {
                if r.links.is_empty() {
                    return Err(format!("empty route {src}->{dst}"));
                }
                // starts at src GPU, ends at dst GPU
                let (sn, sl) = topo.locate(src);
                let (dn, dl) = topo.locate(dst);
                use hetsim::network::topology::NodeRef;
                if topo.link(r.links[0]).from != (NodeRef::Gpu { node: sn, local: sl }) {
                    return Err("route does not start at src".into());
                }
                if topo.link(*r.links.last().unwrap()).to != (NodeRef::Gpu { node: dn, local: dl }) {
                    return Err("route does not end at dst".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_routes_valid_on_random_fabrics_and_node_size_mixes() {
    use hetsim::config::cluster::FabricSpec;
    use hetsim::network::topology::NodeRef;
    check(&cfg(64), |g| {
        // random cluster: 1-4 nodes, each 1-8 GPUs, random architecture
        let nodes = g.rng.range_u64(1, 5) as usize;
        let proto = presets::cluster_hetero(1, 1).unwrap(); // [ampere, hopper]
        let mut cluster = proto.clone();
        cluster.nodes = (0..nodes)
            .map(|_| {
                let mut n = proto.nodes[g.rng.range_u64(0, 2) as usize].clone();
                n.gpus_per_node = g.rng.range_u64(1, 9) as u32;
                n
            })
            .collect();
        // random fabric
        cluster.fabric = match g.rng.range_u64(0, 3) {
            0 => FabricSpec::RailOnly,
            1 => FabricSpec::SingleSwitch,
            _ => FabricSpec::LeafSpine {
                spines: g.rng.range_u64(1, 5) as u32,
                oversubscription: g.rng.range_f64(0.5, 8.0),
            },
        };
        let topo = Topology::build(&cluster)
            .map_err(|e| format!("build failed for {:?}: {e}", cluster.fabric))?;
        let total = topo.total_gpus();
        if total != cluster.total_gpus() {
            return Err(format!("world mismatch {total} != {}", cluster.total_gpus()));
        }
        for _ in 0..24 {
            let src = g.rng.range_u64(0, total as u64) as u32;
            let dst = g.rng.range_u64(0, total as u64) as u32;
            let r = routing::route(&topo, src, dst);
            if src == dst {
                if !r.links.is_empty() {
                    return Err(format!("self-route {src} not empty"));
                }
                continue;
            }
            if r.links.is_empty() {
                return Err(format!("empty route {src}->{dst}"));
            }
            // link-contiguous: hop i's head is hop i+1's tail
            for w in r.links.windows(2) {
                let a = topo.link(w[0]).to;
                let b = topo.link(w[1]).from;
                if a != b {
                    return Err(format!(
                        "disconnected route {src}->{dst} on {:?}: {a:?} != {b:?}",
                        cluster.fabric
                    ));
                }
            }
            // starts at the source GPU, ends at the destination GPU —
            // with the (node, local) decomposition agreeing with the
            // cluster's own prefix-sum mapping
            let (sn, sl) = topo.locate(src);
            let (dn, dl) = topo.locate(dst);
            if cluster.locate(src) != Some((sn, sl)) || cluster.node_of_rank(dst) != Some(dn) {
                return Err(format!("rank mapping disagrees for {src}/{dst}"));
            }
            if topo.link(r.links[0]).from != (NodeRef::Gpu { node: sn, local: sl }) {
                return Err(format!("route {src}->{dst} does not start at src"));
            }
            if topo.link(*r.links.last().unwrap()).to != (NodeRef::Gpu { node: dn, local: dl }) {
                return Err(format!("route {src}->{dst} does not end at dst"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_maxmin_never_oversubscribes_links() {
    use hetsim::engine::Engine;
    use hetsim::network::flow::{FlowId, FlowSim, FlowSpec};
    #[derive(Debug, Clone, Copy)]
    struct Done(FlowId);
    check(&cfg(48), |g| {
        let cluster = presets::cluster_hetero(1, 1).unwrap();
        let topo = Topology::build(&cluster).unwrap();
        let total = topo.total_gpus();
        let mut fs = FlowSim::new(topo);
        let mut eng: Engine<Done> = Engine::new();
        let nflows = g.rng.range_usize(1, 24);
        let specs: Vec<FlowSpec> = (0..nflows)
            .map(|i| FlowSpec {
                src: g.rng.range_u64(0, total as u64) as u32,
                dst: g.rng.range_u64(0, total as u64) as u32,
                bytes: g.rng.range_u64(1, 1 << 26),
                tag: i as u64,
            })
            .collect();
        fs.start_many(&mut eng, &specs, &Done);
        // drain; all flows must complete and total simulated time must be
        // at least the serialization lower bound of the busiest link
        let mut done = 0;
        while let Some(ev) = eng.step() {
            if fs.on_complete(&mut eng, ev.payload.0, ev.id, &Done).is_some() {
                done += 1;
            }
        }
        if done != nflows {
            return Err(format!("{done}/{nflows} flows completed"));
        }
        Ok(())
    });
}

#[test]
fn prop_generated_workloads_always_validate() {
    check(&cfg(40), |g| {
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = g.rng.range_u64(1, 9) as u32;
        model.micro_batch = g.rng.range_u64(1, 5);
        let nodes = g.rng.range_u64(1, 3) as u32;
        let cluster = if g.rng.f64() < 0.5 {
            presets::cluster("hopper", nodes).unwrap()
        } else {
            presets::cluster_hetero(nodes, nodes).unwrap()
        };
        let world = cluster.total_gpus();
        // random valid (tp, pp, dp) factorization of world
        let tps = [1u32, 2, 4, 8];
        let tp = *g.rng.choose(&tps);
        let rest = world / tp;
        let pp = if model.num_layers >= 2 && rest % 2 == 0 && g.rng.f64() < 0.5
            && model.num_layers % 2 == 0
        {
            2
        } else {
            1
        };
        let dp = rest / pp;
        if dp == 0 || tp * pp * dp != world {
            return Ok(()); // skip infeasible combos
        }
        model.global_batch = model.micro_batch * dp as u64 * g.rng.range_u64(1, 4);
        let par = ParallelismSpec { tp, pp, dp };
        let fw = match FrameworkSpec::uniform(&model, &cluster, par) {
            Ok(f) => f,
            Err(_) => return Ok(()), // layers % pp != 0 etc.
        };
        let w = hetsim::workload::aicb::generate(
            &model,
            &cluster,
            &fw,
            &hetsim::workload::aicb::WorkloadOptions::default(),
        )
        .map_err(|e| format!("generate failed: {e}"))?;
        w.validate().map_err(|e| format!("validate failed: {e}"))?;
        // parser round-trip preserves validity
        let text = hetsim::workload::parser::write(&w);
        hetsim::workload::parser::parse(&text).map_err(|e| format!("parse failed: {e}"))?;
        Ok(())
    });
}

#[test]
fn prop_refinement_moves_conserve_layers_and_batch() {
    use hetsim::planner::{apply_move, candidate_moves};
    use hetsim::workload::partition::plan_variable_tp;
    check(&cfg(64), |g| {
        // random per-node TP split of the hetero 1+1 cluster (8 GPUs per
        // node, 1- or 2-stage intra-node pipelines)
        let cluster = presets::cluster_hetero(1, 1).unwrap();
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = g.rng.range_u64(4, 33) as u32;
        model.micro_batch = g.rng.range_u64(1, 5);
        model.global_batch = model.micro_batch * g.rng.range_u64(4, 65);
        let mut splits = Vec::new();
        for _ in 0..2 {
            let small = g.rng.range_u64(0, 5) as u32; // 0 = single stage
            splits.push(if small == 0 { vec![8] } else { vec![8 - small, small] });
        }
        let spec = match plan_variable_tp(&model, &cluster, &splits, true) {
            Ok(s) => s,
            Err(_) => return Ok(()), // infeasible random draw (typed split error)
        };
        let layers_per_group: Vec<u32> = spec
            .groups
            .iter()
            .map(|gr| gr.stages.iter().map(|s| s.num_layers).sum())
            .collect();
        let batch: u64 = spec.groups.iter().map(|gr| gr.batch_share).sum();

        // walk a random sequence of refinement moves
        let mut cur = spec;
        for _ in 0..g.rng.range_usize(1, 12) {
            let moves = candidate_moves(&cur);
            if moves.is_empty() {
                break;
            }
            let mv = g.rng.choose(&moves).clone();
            let next = apply_move(&cur, &mv)
                .ok_or_else(|| format!("emitted move failed to apply: {mv:?}"))?;
            next.validate(&model, &cluster)
                .map_err(|e| format!("move {mv:?} broke validation: {e}"))?;
            cur = next;
        }
        // conservation: per-group layer totals and the global batch
        for (gr, want) in cur.groups.iter().zip(&layers_per_group) {
            let got: u32 = gr.stages.iter().map(|s| s.num_layers).sum();
            if got != *want {
                return Err(format!("group {} layers {got} != {want}", gr.id));
            }
            if gr.stages.iter().any(|s| s.num_layers == 0) {
                return Err(format!("group {} has an empty stage", gr.id));
            }
            if gr.batch_share == 0 {
                return Err(format!("group {} drained below 1 sample", gr.id));
            }
        }
        let got: u64 = cur.groups.iter().map(|gr| gr.batch_share).sum();
        if got != batch {
            return Err(format!("batch {got} != {batch}"));
        }
        Ok(())
    });
}

#[test]
fn prop_folded_simulation_matches_unfolded_exactly() {
    use hetsim::config::cluster::FabricSpec;
    use hetsim::simulator::SimulationBuilder;
    use hetsim::system::fold::FoldMode;
    use hetsim::workload::schedule::ScheduleKind;
    use std::sync::atomic::{AtomicUsize, Ordering};

    // folding is exact, not approximate: iteration time and the busy
    // accumulators must match the unfolded run bit-for-bit whenever
    // fold=auto engages (DESIGN.md §25)
    let folded_cases = AtomicUsize::new(0);
    check(&cfg(40), |g| {
        let nodes = g.rng.range_u64(1, 4) as u32;
        let mut cluster = match g.rng.range_u64(0, 3) {
            0 => presets::cluster("ampere", nodes * 2).unwrap(),
            1 => presets::cluster("hopper", nodes * 2).unwrap(),
            _ => presets::cluster_hetero(nodes, nodes).unwrap(),
        };
        cluster.fabric = match g.rng.range_u64(0, 3) {
            0 => FabricSpec::RailOnly,
            1 => FabricSpec::SingleSwitch,
            _ => FabricSpec::LeafSpine {
                spines: g.rng.range_u64(1, 4) as u32,
                oversubscription: g.rng.range_f64(1.0, 4.0),
            },
        };
        let world = cluster.total_gpus();
        let tp = *g.rng.choose(&[1u32, 2, 4, 8, 16]);
        if world % tp != 0 {
            return Ok(());
        }
        let dp = world / tp;
        if dp < 2 {
            return Ok(()); // folding needs a data-parallel dimension
        }
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = g.rng.range_u64(1, 5) as u32;
        model.micro_batch = g.rng.range_u64(1, 3);
        model.global_batch = model.micro_batch * dp as u64 * g.rng.range_u64(1, 3);
        let schedule = *g.rng.choose(&[
            ScheduleKind::GPipe,
            ScheduleKind::OneFOneB,
            ScheduleKind::Interleaved1F1B { vpp: 2 },
        ]);
        let par = ParallelismSpec { tp, pp: 1, dp };
        let run = |mode: FoldMode| {
            let sim = SimulationBuilder::new(model.clone(), cluster.clone())
                .parallelism(par)
                .schedule(schedule)
                .fold(mode)
                .build()
                .map_err(|e| format!("build({mode:?}) failed: {e}"))?;
            let was_folded = sim.folded();
            let rep = sim
                .run_iteration()
                .map_err(|e| format!("run({mode:?}) failed: {e}"))?;
            Ok::<_, String>((was_folded, rep))
        };
        let (off_folded, off) = run(FoldMode::Off)?;
        let (auto_folded, auto_) = run(FoldMode::Auto)?;
        if off_folded {
            return Err("fold=off produced a folded simulation".into());
        }
        if auto_folded {
            folded_cases.fetch_add(1, Ordering::Relaxed);
        }
        let ctx = format!(
            "{} fabric={:?} tp={tp} dp={dp} layers={} mb={} gb={} sched={:?} folded={auto_folded}",
            cluster.name,
            cluster.fabric,
            model.num_layers,
            model.micro_batch,
            model.global_batch,
            schedule,
        );
        if auto_.iteration_time != off.iteration_time {
            return Err(format!(
                "iteration time diverged ({} != {}): {ctx}",
                auto_.iteration_time, off.iteration_time
            ));
        }
        if auto_.compute_busy != off.compute_busy {
            return Err(format!(
                "compute busy diverged ({} != {}): {ctx}",
                auto_.compute_busy, off.compute_busy
            ));
        }
        if auto_.comm_busy != off.comm_busy {
            return Err(format!(
                "comm busy diverged ({} != {}): {ctx}",
                auto_.comm_busy, off.comm_busy
            ));
        }
        Ok(())
    });
    assert!(
        folded_cases.load(Ordering::Relaxed) > 0,
        "no random case ever folded — the property is vacuous"
    );
}

#[test]
fn prop_empty_fault_spec_is_bit_identical_to_no_faults() {
    use hetsim::config::cluster::FabricSpec;
    use hetsim::simulator::SimulationBuilder;
    use hetsim::system::failure::FaultSpec;
    use hetsim::system::fold::FoldMode;
    use hetsim::workload::schedule::ScheduleKind;

    // the fault layer must be zero-cost when off: configuring an empty
    // FaultSpec must reproduce the unconfigured run bit-for-bit — same
    // timing, same event counts, same folding decision (DESIGN.md §26)
    check(&cfg(40), |g| {
        let nodes = g.rng.range_u64(1, 4) as u32;
        let mut cluster = match g.rng.range_u64(0, 3) {
            0 => presets::cluster("ampere", nodes * 2).unwrap(),
            1 => presets::cluster("hopper", nodes * 2).unwrap(),
            _ => presets::cluster_hetero(nodes, nodes).unwrap(),
        };
        cluster.fabric = match g.rng.range_u64(0, 3) {
            0 => FabricSpec::RailOnly,
            1 => FabricSpec::SingleSwitch,
            _ => FabricSpec::LeafSpine {
                spines: g.rng.range_u64(1, 4) as u32,
                oversubscription: g.rng.range_f64(1.0, 4.0),
            },
        };
        let world = cluster.total_gpus();
        let tp = *g.rng.choose(&[1u32, 2, 4, 8, 16]);
        if world % tp != 0 {
            return Ok(());
        }
        let dp = world / tp;
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = g.rng.range_u64(1, 5) as u32;
        model.micro_batch = g.rng.range_u64(1, 3);
        model.global_batch = model.micro_batch * dp as u64 * g.rng.range_u64(1, 3);
        let schedule = *g.rng.choose(&[
            ScheduleKind::GPipe,
            ScheduleKind::OneFOneB,
            ScheduleKind::Interleaved1F1B { vpp: 2 },
        ]);
        let par = ParallelismSpec { tp, pp: 1, dp };
        let run = |spec: Option<FaultSpec>| {
            let sim = SimulationBuilder::new(model.clone(), cluster.clone())
                .parallelism(par)
                .schedule(schedule)
                .fold(FoldMode::Auto)
                .faults(spec)
                .build()
                .map_err(|e| format!("build failed: {e}"))?;
            let folded = sim.folded();
            let rep = sim.run_iteration().map_err(|e| format!("run failed: {e}"))?;
            Ok::<_, String>((folded, rep))
        };
        let (fold_none, none) = run(None)?;
        let (fold_empty, empty) = run(Some(FaultSpec::default()))?;
        let ctx = format!("{} tp={tp} dp={dp} sched={schedule:?}", cluster.name);
        if fold_none != fold_empty {
            return Err(format!(
                "empty spec changed the folding decision ({fold_none} vs {fold_empty}): {ctx}"
            ));
        }
        if none.iteration_time != empty.iteration_time {
            return Err(format!(
                "iteration time diverged ({} != {}): {ctx}",
                none.iteration_time, empty.iteration_time
            ));
        }
        if none.events_processed != empty.events_processed {
            return Err(format!(
                "event count diverged ({} != {}): {ctx}",
                none.events_processed, empty.events_processed
            ));
        }
        if none.flows_completed != empty.flows_completed
            || none.compute_busy != empty.compute_busy
            || none.comm_busy != empty.comm_busy
            || none.fault != empty.fault
        {
            return Err(format!("report diverged under empty fault spec: {ctx}"));
        }
        Ok(())
    });
}

#[test]
fn prop_goodput_monotone_non_increasing_in_failure_rate() {
    use hetsim::config::cluster::ClusterSpec;
    use hetsim::report::goodput::{walk, GoodputInput};
    use hetsim::system::failure::{mtbf_schedule, CheckpointSpec, RepairSpec, SCALE_CAP};
    use std::sync::atomic::{AtomicUsize, Ordering};

    // mtbf_schedule thins one master draw, so a lower scale yields a
    // subset of a higher scale's events, and the goodput walk only ever
    // loses from extra events — together: goodput is monotone
    // non-increasing in the failure-rate scale (DESIGN.md §26).
    // Pinned to the zero-repair regime: with a repair window a node
    // loss can moot a later repairable outage's charge, so strict
    // monotonicity only holds when NIC/link faults carry no window.
    let distinct = AtomicUsize::new(0);
    check(&cfg(100), |g| {
        let nodes = g.rng.range_u64(1, 5) as u32;
        let cluster = match g.rng.range_u64(0, 3) {
            0 => presets::cluster("ampere", nodes).unwrap(),
            1 => presets::cluster("hopper", nodes).unwrap(),
            _ => presets::cluster_hetero(nodes, nodes).unwrap(),
        };
        let model = presets::model("gpt-6.7b").unwrap();
        let iter_s = g.rng.range_f64(0.1, 30.0);
        let input = GoodputInput {
            model: &model,
            cluster: &cluster,
            iteration: Time::from_secs(iter_s),
            dp: g.rng.range_u64(1, 9) as u32,
            checkpoint: CheckpointSpec {
                interval_iters: g.rng.range_u64(1, 200),
                write_gbps: g.rng.range_f64(1.0, 100.0),
                restart_warmup_s: g.rng.range_f64(0.0, 600.0),
            },
            horizon_s: g.rng.range_f64(3_600.0, 14.0 * 86_400.0),
            repair: RepairSpec { nic_s: 0.0, link_s: 0.0 },
            degraded: None,
            comm_fraction: 0.0,
        };
        let seed = g.rng.range_u64(0, 1 << 48);
        let mut lo_scale = g.rng.range_f64(0.0, SCALE_CAP);
        let mut hi_scale = g.rng.range_f64(0.0, SCALE_CAP);
        if lo_scale > hi_scale {
            std::mem::swap(&mut lo_scale, &mut hi_scale);
        }
        // synthetic but consistent re-plan model: losing nodes slows
        // the per-iteration time proportionally
        let full = cluster.nodes.len() as f64;
        let mut replan = |c: &ClusterSpec| {
            Some(Time::from_secs(iter_s * full / c.nodes.len().max(1) as f64))
        };
        let lo_events = mtbf_schedule(&cluster, input.horizon_s, lo_scale, seed);
        let hi_events = mtbf_schedule(&cluster, input.horizon_s, hi_scale, seed);
        if lo_events.len() > hi_events.len() {
            return Err(format!(
                "schedule not nested: scale {lo_scale:.3} drew {} events, {hi_scale:.3} drew {}",
                lo_events.len(),
                hi_events.len()
            ));
        }
        if hi_events.len() > lo_events.len() {
            distinct.fetch_add(1, Ordering::Relaxed);
        }
        let lo = walk(&input, &lo_events, &mut replan);
        let hi = walk(&input, &hi_events, &mut replan);
        let tol = lo.goodput_tokens_per_s.abs() * 1e-9 + 1e-9;
        if hi.goodput_tokens_per_s > lo.goodput_tokens_per_s + tol {
            return Err(format!(
                "goodput increased with failure rate: {:.3} tok/s at scale {lo_scale:.3} but \
                 {:.3} tok/s at scale {hi_scale:.3} ({} vs {} events, {} nodes)",
                lo.goodput_tokens_per_s,
                hi.goodput_tokens_per_s,
                lo_events.len(),
                hi_events.len(),
                cluster.nodes.len()
            ));
        }
        Ok(())
    });
    assert!(
        distinct.load(Ordering::Relaxed) > 0,
        "no random case ever drew different schedules — the property is vacuous"
    );
}

#[test]
fn prop_fault_sweep_deterministic_across_thread_counts() {
    use hetsim::planner::PlanOptions;
    use hetsim::report::goodput::{sweep, SweepOptions};
    use hetsim::system::fold::FoldMode;

    // the goodput walk is sequential over a pre-drawn schedule, so the
    // whole sweep — plan search, fault trajectory, ranking — must not
    // depend on how many worker threads scored the candidates
    check(&cfg(3), |g| {
        let cluster = if g.rng.f64() < 0.5 {
            presets::cluster("hopper", 2).unwrap()
        } else {
            presets::cluster_hetero(1, 1).unwrap()
        };
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = 2;
        model.global_batch = 8;
        model.micro_batch = 1;
        let seed = g.rng.range_u64(0, 1 << 32);
        let scale = g.rng.range_f64(4.0, 16.0);
        let mut reports = Vec::new();
        for threads in [1usize, 4, 8] {
            let opts = SweepOptions {
                plan: PlanOptions {
                    microbatch_limit: Some(1),
                    threads,
                    refine_steps: 0,
                    fold: FoldMode::Off,
                },
                top: 3,
                horizon_s: 4.0 * 86_400.0,
                mtbf_scale: scale,
                seed,
                ..Default::default()
            };
            let rep = sweep(&model, &cluster, &opts)
                .map_err(|e| format!("sweep(threads={threads}) failed: {e}"))?;
            reports.push((threads, rep));
        }
        let (_, base) = &reports[0];
        if base.entries.is_empty() {
            return Err("sweep ranked no plans".into());
        }
        for (threads, rep) in &reports[1..] {
            if rep.entries.len() != base.entries.len() {
                return Err(format!(
                    "{} entries with {threads} threads, {} with 1",
                    rep.entries.len(),
                    base.entries.len()
                ));
            }
            for (a, b) in rep.entries.iter().zip(&base.entries) {
                if a.plan != b.plan || a.iteration != b.iteration || a.dp != b.dp {
                    return Err(format!(
                        "ranking diverged at {threads} threads: {} vs {}",
                        a.plan, b.plan
                    ));
                }
                if a.goodput != b.goodput {
                    return Err(format!(
                        "fault trajectory diverged at {threads} threads on {}: {:?} vs {:?}",
                        a.plan, a.goodput, b.goodput
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_degraded_zero_repair_matches_fail_stop_baseline() {
    use hetsim::config::cluster::ClusterSpec;
    use hetsim::report::goodput::{walk, GoodputInput};
    use hetsim::system::failure::{
        mtbf_schedule, CheckpointSpec, DegradedModel, FaultKind, RepairSpec, SCALE_CAP,
    };
    use std::sync::atomic::{AtomicUsize, Ordering};

    // with zero-length repair windows a repairable outage ends the
    // instant it begins: the degraded-window machinery must charge
    // exactly what the plain fail-stop baseline charges, bit for bit
    // (DESIGN.md §28)
    let repairable = AtomicUsize::new(0);
    check(&cfg(60), |g| {
        let nodes = g.rng.range_u64(1, 4) as u32;
        let cluster = if g.rng.f64() < 0.5 {
            presets::cluster("hopper", nodes).unwrap()
        } else {
            presets::cluster_hetero(nodes, nodes).unwrap()
        };
        let degraded = DegradedModel::derive(&cluster).map_err(|e| e.to_string())?;
        let model = presets::model("gpt-6.7b").unwrap();
        let iter_s = g.rng.range_f64(0.1, 30.0);
        let horizon_s = g.rng.range_f64(3_600.0, 7.0 * 86_400.0);
        let base = GoodputInput {
            model: &model,
            cluster: &cluster,
            iteration: Time::from_secs(iter_s),
            dp: g.rng.range_u64(1, 9) as u32,
            checkpoint: CheckpointSpec {
                interval_iters: g.rng.range_u64(1, 200),
                write_gbps: g.rng.range_f64(1.0, 100.0),
                restart_warmup_s: g.rng.range_f64(0.0, 600.0),
            },
            horizon_s,
            repair: RepairSpec { nic_s: 0.0, link_s: 0.0 },
            degraded: None,
            comm_fraction: g.rng.f64(),
        };
        let scale = g.rng.range_f64(0.0, SCALE_CAP);
        let seed = g.rng.range_u64(0, 1 << 48);
        let events = mtbf_schedule(&cluster, horizon_s, scale, seed);
        repairable.fetch_add(
            events
                .iter()
                .filter(|e| {
                    matches!(e.kind, FaultKind::NicFail { .. } | FaultKind::LinkFail { .. })
                })
                .count(),
            Ordering::Relaxed,
        );
        let full = cluster.nodes.len() as f64;
        let mut replan = |c: &ClusterSpec| {
            Some(Time::from_secs(iter_s * full / c.nodes.len().max(1) as f64))
        };
        let fail_stop = walk(&base, &events, &mut replan);
        let with_model =
            walk(&GoodputInput { degraded: Some(&degraded), ..base }, &events, &mut replan);
        if with_model != fail_stop {
            return Err(format!(
                "zero-repair degraded walk diverged from the fail-stop baseline: \
                 {:.6} vs {:.6} tok/s over {} events",
                with_model.goodput_tokens_per_s,
                fail_stop.goodput_tokens_per_s,
                events.len()
            ));
        }
        Ok(())
    });
    assert!(
        repairable.load(Ordering::Relaxed) > 0,
        "no schedule ever drew a repairable fault — the property is vacuous"
    );
}

#[test]
fn prop_mc_n1_matches_single_walk() {
    use hetsim::config::cluster::ClusterSpec;
    use hetsim::report::goodput::{monte_carlo, trajectory_seed, walk, GoodputInput};
    use hetsim::system::failure::{mtbf_schedule, CheckpointSpec, RepairSpec, SCALE_CAP};

    // trajectory 0 reuses the base seed verbatim, so a 1-trajectory
    // Monte-Carlo run is the deterministic walk, bit for bit — the MC
    // layer adds spread, never a different model (DESIGN.md §28)
    check(&cfg(40), |g| {
        let nodes = g.rng.range_u64(1, 4) as u32;
        let cluster = presets::cluster("hopper", nodes).unwrap();
        let model = presets::model("gpt-6.7b").unwrap();
        let iter_s = g.rng.range_f64(0.1, 30.0);
        let horizon_s = g.rng.range_f64(3_600.0, 7.0 * 86_400.0);
        let input = GoodputInput {
            model: &model,
            cluster: &cluster,
            iteration: Time::from_secs(iter_s),
            dp: g.rng.range_u64(1, 9) as u32,
            checkpoint: CheckpointSpec {
                interval_iters: g.rng.range_u64(1, 200),
                write_gbps: g.rng.range_f64(1.0, 100.0),
                restart_warmup_s: g.rng.range_f64(0.0, 600.0),
            },
            horizon_s,
            repair: RepairSpec::default(),
            degraded: None,
            comm_fraction: 0.25,
        };
        let seed = g.rng.range_u64(0, 1 << 48);
        let scale = g.rng.range_f64(0.0, SCALE_CAP);
        if trajectory_seed(seed, 0) != seed {
            return Err(format!("trajectory 0 must reuse the base seed {seed} verbatim"));
        }
        let full = cluster.nodes.len() as f64;
        let replan = |c: &ClusterSpec| {
            Some(Time::from_secs(iter_s * full / c.nodes.len().max(1) as f64))
        };
        let draw = |i: u32| mtbf_schedule(&cluster, horizon_s, scale, trajectory_seed(seed, i));
        let threads = 1 + g.rng.range_u64(0, 4) as usize;
        let reports = monte_carlo(&input, draw, 1, threads, replan);
        let mut rm = replan;
        let single = walk(&input, &draw(0), &mut rm);
        if reports.len() != 1 || reports[0] != single {
            return Err(format!(
                "1-trajectory Monte-Carlo diverged from the single walk: {:?} vs {:?}",
                reports.first(),
                single
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_mc_deterministic_across_threads_and_nested_in_trajectory_count() {
    use hetsim::config::cluster::ClusterSpec;
    use hetsim::report::goodput::{monte_carlo, trajectory_seed, GoodputInput};
    use hetsim::system::failure::{mtbf_schedule, CheckpointSpec, RepairSpec};
    use std::sync::atomic::{AtomicUsize, Ordering};

    // per-trajectory seeds depend only on the trajectory index, and the
    // reduction is index-ordered: the report vector must be
    // byte-identical for any worker count, and the first N trajectories
    // of a 2N-run must equal the N-run exactly (DESIGN.md §28)
    let eventful = AtomicUsize::new(0);
    check(&cfg(15), |g| {
        let cluster = presets::cluster("hopper", 1 + g.rng.range_u64(0, 3) as u32).unwrap();
        let model = presets::model("gpt-6.7b").unwrap();
        let iter_s = g.rng.range_f64(0.1, 30.0);
        let horizon_s = g.rng.range_f64(7.0 * 86_400.0, 21.0 * 86_400.0);
        let input = GoodputInput {
            model: &model,
            cluster: &cluster,
            iteration: Time::from_secs(iter_s),
            dp: g.rng.range_u64(1, 9) as u32,
            checkpoint: CheckpointSpec {
                interval_iters: g.rng.range_u64(1, 200),
                write_gbps: g.rng.range_f64(1.0, 100.0),
                restart_warmup_s: g.rng.range_f64(0.0, 600.0),
            },
            horizon_s,
            repair: RepairSpec::default(),
            degraded: None,
            comm_fraction: 0.25,
        };
        let seed = g.rng.range_u64(0, 1 << 48);
        let scale = g.rng.range_f64(4.0, 12.0);
        let full = cluster.nodes.len() as f64;
        let replan = |c: &ClusterSpec| {
            Some(Time::from_secs(iter_s * full / c.nodes.len().max(1) as f64))
        };
        let draw = |i: u32| mtbf_schedule(&cluster, horizon_s, scale, trajectory_seed(seed, i));
        let n = 2 + g.rng.range_u64(0, 5) as u32;
        let base = monte_carlo(&input, draw, n, 1, replan);
        eventful.fetch_add(
            base.iter().filter(|r| r.fail_stops + r.link_outages + r.stragglers > 0).count(),
            Ordering::Relaxed,
        );
        for threads in [4usize, 8] {
            let rep = monte_carlo(&input, draw, n, threads, replan);
            if rep != base {
                return Err(format!(
                    "Monte-Carlo reports diverged between 1 and {threads} threads \
                     over {n} trajectories"
                ));
            }
        }
        let doubled = monte_carlo(&input, draw, 2 * n, 3, replan);
        if doubled[..n as usize] != base[..] {
            return Err(format!(
                "trajectory sets not nested: first {n} of {} diverged from the {n}-run",
                2 * n
            ));
        }
        Ok(())
    });
    assert!(
        eventful.load(Ordering::Relaxed) > 0,
        "no trajectory ever drew a fault — the property is vacuous"
    );
}

#[test]
fn prop_domain_schedule_nested_and_correlated() {
    use hetsim::system::failure::{domain_schedule, FailureDomains, FaultKind, SCALE_CAP};
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicUsize, Ordering};

    // domain blasts use the same thinning construction as the per-node
    // MTBF schedules, so a lower rate scale draws an exact subset of a
    // higher scale's blasts; and every blast must strike complete
    // failure domains, never partial ones (DESIGN.md §28)
    let distinct = AtomicUsize::new(0);
    let multi = AtomicUsize::new(0);
    check(&cfg(100), |g| {
        let nodes = g.rng.range_u64(2, 9) as u32;
        let cluster = presets::cluster("ampere", nodes).unwrap();
        let rack = g.rng.range_u64(1, 5) as u32;
        let domains = FailureDomains::derive(&cluster, rack);
        let horizon_s = g.rng.range_f64(10.0 * 86_400.0, 30.0 * 86_400.0);
        let mtbf_hours = g.rng.range_f64(100.0, 2_000.0);
        let seed = g.rng.range_u64(0, 1 << 48);
        let mut lo = g.rng.range_f64(0.0, SCALE_CAP);
        let mut hi = g.rng.range_f64(1.0, SCALE_CAP);
        if lo > hi {
            std::mem::swap(&mut lo, &mut hi);
        }
        let lo_ev = domain_schedule(&cluster, &domains, horizon_s, mtbf_hours, lo, seed);
        let hi_ev = domain_schedule(&cluster, &domains, horizon_s, mtbf_hours, hi, seed);
        // nested: every low-scale blast appears verbatim in the
        // high-scale schedule, in the same relative order
        let mut it = hi_ev.iter();
        for e in &lo_ev {
            if !it.any(|h| h == e) {
                return Err(format!(
                    "scale {lo:.3} event at t={} missing from scale {hi:.3} schedule \
                     ({} vs {} events)",
                    e.at_s,
                    lo_ev.len(),
                    hi_ev.len()
                ));
            }
        }
        if hi_ev.len() > lo_ev.len() {
            distinct.fetch_add(1, Ordering::Relaxed);
        }
        // correlated: group by bit-exact blast instant; every group
        // must decompose into complete domains
        let mut by_t: HashMap<u64, Vec<u32>> = HashMap::new();
        for e in &hi_ev {
            if !matches!(e.kind, FaultKind::NodeFail { .. }) {
                return Err(format!("domain schedule drew a non-node fault: {:?}", e.kind));
            }
            by_t.entry(e.at_s.to_bits()).or_default().push(e.kind.node());
        }
        for (t, mut struck) in by_t {
            struck.sort_unstable();
            if struck.len() > 1 {
                multi.fetch_add(1, Ordering::Relaxed);
            }
            let mut rest: &[u32] = &struck;
            while !rest.is_empty() {
                let dom = domains.members.iter().find(|m| m.first() == rest.first());
                match dom {
                    Some(m) if rest.len() >= m.len() && &rest[..m.len()] == m.as_slice() => {
                        rest = &rest[m.len()..];
                    }
                    _ => {
                        return Err(format!(
                            "blast at t(bits)={t} struck {struck:?}, not a union of \
                             complete domains {:?}",
                            domains.members
                        ));
                    }
                }
            }
        }
        Ok(())
    });
    assert!(
        distinct.load(Ordering::Relaxed) > 0,
        "no random case ever drew different schedules — nesting is vacuous"
    );
    assert!(
        multi.load(Ordering::Relaxed) > 0,
        "no blast ever struck a multi-node domain — correlation is vacuous"
    );
}

#[test]
fn prop_poisson_trace_reproducible_and_nested_in_rate_scale() {
    use hetsim::workload::serve::{poisson_trace, PoissonSpec, RATE_SCALE_CAP};
    use std::sync::atomic::{AtomicUsize, Ordering};

    // the serving trace uses the same thinning construction as the MTBF
    // fault schedules (DESIGN.md §26/§27): candidates are drawn at the
    // cap rate and kept with probability scale/cap, so the same seed
    // always reproduces the same trace and a lower scale draws an exact
    // subset of a higher scale's requests
    let distinct = AtomicUsize::new(0);
    check(&cfg(150), |g| {
        let mut spec = PoissonSpec {
            rate_per_s: g.rng.range_f64(0.1, 20.0),
            horizon_s: g.rng.range_f64(0.5, 30.0),
            scale: 1.0,
            prompt_tokens: g.rng.range_u64(1, 2048),
            output_tokens: g.rng.range_u64(1, 256),
        };
        let seed = g.rng.range_u64(0, 1 << 48);
        let mut lo_scale = g.rng.range_f64(0.0, RATE_SCALE_CAP);
        let mut hi_scale = g.rng.range_f64(0.0, RATE_SCALE_CAP);
        if lo_scale > hi_scale {
            std::mem::swap(&mut lo_scale, &mut hi_scale);
        }
        spec.scale = lo_scale;
        let lo_a = poisson_trace(&spec, seed);
        let lo_b = poisson_trace(&spec, seed);
        if lo_a != lo_b {
            return Err(format!("same seed {seed} produced different traces"));
        }
        spec.scale = hi_scale;
        let hi = poisson_trace(&spec, seed);
        // nested: every low-scale request appears verbatim in the
        // high-scale trace, in the same relative order
        let mut it = hi.iter();
        for r in &lo_a {
            if !it.any(|h| h == r) {
                return Err(format!(
                    "scale {lo_scale:.3} request at t={} missing from scale {hi_scale:.3} \
                     trace ({} vs {} requests)",
                    r.arrival_s,
                    lo_a.len(),
                    hi.len()
                ));
            }
        }
        // arrivals are sorted and inside the horizon
        for w in hi.windows(2) {
            if w[1].arrival_s < w[0].arrival_s {
                return Err("trace not sorted by arrival".into());
            }
        }
        if hi.iter().any(|r| r.arrival_s < 0.0 || r.arrival_s >= spec.horizon_s) {
            return Err("arrival outside horizon".into());
        }
        if hi.len() > lo_a.len() {
            distinct.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    });
    assert!(
        distinct.load(Ordering::Relaxed) > 0,
        "no random case ever drew different traces — the property is vacuous"
    );
}

#[test]
fn prop_serving_conserves_requests_and_respects_kv_budget() {
    use hetsim::config::cluster::FabricSpec;
    use hetsim::system::serve_scheduler::ServeSim;
    use hetsim::workload::serve::{PoissonSpec, Request, ServePolicy, ServeSpec};
    use std::sync::atomic::{AtomicUsize, Ordering};

    // the scheduler reserves each request's full prompt+output KV
    // footprint at admission, so (a) every materialized request
    // completes exactly once, (b) no group's peak KV residency ever
    // exceeds its budget, and (c) the report is byte-identical no
    // matter how many threads priced the cost tables (DESIGN.md §27)
    let nonempty = AtomicUsize::new(0);
    check(&cfg(40), |g| {
        // random cluster: 1-3 nodes, each 1-8 GPUs, random architecture
        let nodes = g.rng.range_u64(1, 4) as usize;
        let proto = presets::cluster_hetero(1, 1).unwrap(); // [ampere, hopper]
        let mut cluster = proto.clone();
        cluster.nodes = (0..nodes)
            .map(|_| {
                let mut n = proto.nodes[g.rng.range_u64(0, 2) as usize].clone();
                n.gpus_per_node = g.rng.range_u64(1, 9) as u32;
                n
            })
            .collect();
        cluster.fabric = match g.rng.range_u64(0, 3) {
            0 => FabricSpec::RailOnly,
            1 => FabricSpec::SingleSwitch,
            _ => FabricSpec::LeafSpine {
                spines: g.rng.range_u64(1, 4) as u32,
                oversubscription: g.rng.range_f64(1.0, 4.0),
            },
        };
        // a shrunk model so the weights fit even a single-GPU node
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = g.rng.range_u64(2, 9) as u32;
        // random trace: a few explicit requests plus an optional
        // Poisson burst, random policy and batch cap
        let mut requests = Vec::new();
        for _ in 0..g.rng.range_usize(0, 6) {
            requests.push(Request {
                arrival_s: g.rng.range_f64(0.0, 2.0),
                prompt_tokens: g.rng.range_u64(1, 513),
                output_tokens: g.rng.range_u64(1, 65),
                weight: g.rng.range_f64(0.1, 4.0),
            });
        }
        let poisson = if g.rng.f64() < 0.7 {
            Some(PoissonSpec {
                rate_per_s: g.rng.range_f64(0.5, 10.0),
                horizon_s: g.rng.range_f64(0.5, 4.0),
                scale: 1.0,
                prompt_tokens: g.rng.range_u64(1, 513),
                output_tokens: g.rng.range_u64(1, 65),
            })
        } else {
            None
        };
        if requests.is_empty() && poisson.is_none() {
            return Ok(()); // empty spec is covered by the unit tests
        }
        let spec = ServeSpec {
            requests,
            poisson,
            policy: *g.rng.choose(&[ServePolicy::Fifo, ServePolicy::Srpt, ServePolicy::Wsrpt]),
            max_batch: g.rng.range_u64(1, 9) as u32,
            kv_frac: g.rng.range_f64(0.1, 1.0),
            seed: g.rng.range_u64(0, 1 << 48),
        };
        let sim = match ServeSim::new(model, cluster, spec) {
            Ok(s) => s,
            // a tiny random node may not fit even the shrunk model, or
            // a small kv_frac may not fit the largest random request —
            // both are legitimate typed rejections, not failures
            Err(_) => return Ok(()),
        };
        let total = sim.requests().len();
        let rep = sim.run(1).map_err(|e| format!("run failed: {e}"))?;
        if total > 0 {
            nonempty.fetch_add(1, Ordering::Relaxed);
        }
        // conservation: every request completes exactly once
        let served: u64 = rep.groups.iter().map(|gr| gr.requests).sum();
        if served != total as u64 || rep.requests_total != total as u64 {
            return Err(format!("served {served} of {total} requests"));
        }
        let want_tokens: u64 = sim.requests().iter().map(|r| r.output_tokens).sum();
        if rep.tokens_out_total != want_tokens {
            return Err(format!("tokens out {} != {want_tokens}", rep.tokens_out_total));
        }
        if rep.latency.count != total || rep.ttft.count != total {
            return Err(format!(
                "latency samples {} / ttft samples {} != {total}",
                rep.latency.count, rep.ttft.count
            ));
        }
        // KV residency never exceeds any group's budget
        for gr in &rep.groups {
            if gr.kv_peak_tokens > gr.kv_budget_tokens {
                return Err(format!(
                    "group {} peak {} tokens over budget {}",
                    gr.node, gr.kv_peak_tokens, gr.kv_budget_tokens
                ));
            }
        }
        // thread invariance: pricing parallelism must not leak into
        // the report
        let threads = g.rng.range_usize(2, 9);
        let again = sim.run(threads).map_err(|e| format!("run({threads}) failed: {e}"))?;
        if again.render() != rep.render() {
            return Err(format!("report diverged at {threads} threads"));
        }
        Ok(())
    });
    assert!(
        nonempty.load(Ordering::Relaxed) > 0,
        "no random case ever served a request — the property is vacuous"
    );
}

#[test]
fn prop_bnb_bound_is_admissible() {
    use hetsim::config::cluster::FabricSpec;
    use hetsim::planner::Bounder;
    use hetsim::simulator::SimulationBuilder;
    use hetsim::system::fold::FoldMode;
    use hetsim::workload::aicb::WorkloadOptions;
    use hetsim::workload::schedule::ScheduleKind;
    use std::sync::atomic::{AtomicUsize, Ordering};

    // the branch-and-bound prune rule is only sound if the analytical
    // lower bound never exceeds the simulated iteration time — on any
    // cluster, fabric, schedule, or microbatch budget (DESIGN.md §29)
    let nontrivial = AtomicUsize::new(0);
    check(&cfg(40), |g| {
        let nodes = g.rng.range_u64(1, 4) as u32;
        let mut cluster = match g.rng.range_u64(0, 3) {
            0 => presets::cluster("ampere", nodes).unwrap(),
            1 => presets::cluster("hopper", nodes).unwrap(),
            _ => presets::cluster_hetero(nodes, nodes).unwrap(),
        };
        cluster.fabric = match g.rng.range_u64(0, 3) {
            0 => FabricSpec::RailOnly,
            1 => FabricSpec::SingleSwitch,
            _ => FabricSpec::LeafSpine {
                spines: g.rng.range_u64(1, 4) as u32,
                oversubscription: g.rng.range_f64(1.0, 4.0),
            },
        };
        let world = cluster.total_gpus();
        let tp = *g.rng.choose(&[1u32, 2, 4, 8]);
        if world % tp != 0 {
            return Ok(());
        }
        let rest = world / tp;
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = g.rng.range_u64(1, 5) as u32 * 2;
        model.micro_batch = g.rng.range_u64(1, 3);
        let pp = if rest % 2 == 0 && g.rng.f64() < 0.4 { 2 } else { 1 };
        let dp = rest / pp;
        if dp == 0 {
            return Ok(());
        }
        model.global_batch = model.micro_batch * dp as u64 * g.rng.range_u64(1, 4);
        let schedule = *g.rng.choose(&[
            ScheduleKind::GPipe,
            ScheduleKind::OneFOneB,
            ScheduleKind::Interleaved1F1B { vpp: 2 },
        ]);
        let par = ParallelismSpec { tp, pp, dp };
        let fw = match FrameworkSpec::uniform(&model, &cluster, par) {
            Ok(f) => f.with_schedule(schedule),
            Err(_) => return Ok(()), // infeasible random draw
        };
        let limit = match g.rng.range_u64(0, 3) {
            0 => None,
            n => Some(n),
        };
        let topo = Topology::build(&cluster).map_err(|e| format!("topology: {e}"))?;
        let mut bounder = Bounder::new(&topo);
        let lb = bounder
            .bound(&model, &cluster, &fw, limit)
            .map_err(|e| format!("bound failed: {e}"))?;
        let sim = SimulationBuilder::new(model.clone(), cluster.clone())
            .parallelism(par)
            .framework(fw)
            .workload_options(WorkloadOptions { microbatch_limit: limit, ..Default::default() })
            .fold(FoldMode::Off)
            .build()
            .map_err(|e| format!("build failed: {e}"))?;
        let rep = sim.run_iteration().map_err(|e| format!("run failed: {e}"))?;
        if lb > rep.iteration_time {
            return Err(format!(
                "bound {lb} exceeds simulated {} ({} fabric={:?} tp={tp} pp={pp} dp={dp} \
                 layers={} mb={} limit={limit:?} sched={schedule:?})",
                rep.iteration_time,
                cluster.name,
                cluster.fabric,
                model.num_layers,
                model.micro_batch,
            ));
        }
        if lb > Time::ZERO {
            nontrivial.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    });
    assert!(
        nontrivial.load(Ordering::Relaxed) > 0,
        "every bound was zero — admissibility is vacuous"
    );
}

#[test]
fn prop_cutoff_simulation_bit_identical_and_strict() {
    use hetsim::simulator::{EvalContext, ScoreOutcome, SimulationBuilder};
    use hetsim::system::fold::FoldMode;
    use hetsim::workload::schedule::ScheduleKind;
    use std::sync::atomic::{AtomicUsize, Ordering};

    // the incumbent cutoff must be a pure abort knob: scoring with no
    // cutoff, an unreachable cutoff, or a cutoff exactly equal to the
    // final clock reproduces plain scoring bit for bit (the abort rule
    // is strictly `clock > limit`), while any cutoff strictly below
    // the final clock aborts (DESIGN.md §29). Each variant gets a
    // fresh EvalContext so the score cache cannot mask a divergence.
    let aborted = AtomicUsize::new(0);
    check(&cfg(24), |g| {
        let nodes = g.rng.range_u64(1, 3) as u32;
        let cluster = match g.rng.range_u64(0, 3) {
            0 => presets::cluster("ampere", nodes).unwrap(),
            1 => presets::cluster("hopper", nodes).unwrap(),
            _ => presets::cluster_hetero(nodes, nodes).unwrap(),
        };
        let world = cluster.total_gpus();
        let tp = *g.rng.choose(&[1u32, 2, 4, 8]);
        if world % tp != 0 {
            return Ok(());
        }
        let dp = world / tp;
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = g.rng.range_u64(1, 4) as u32;
        model.micro_batch = g.rng.range_u64(1, 3);
        model.global_batch = model.micro_batch * dp as u64 * g.rng.range_u64(1, 3);
        let schedule = *g.rng.choose(&[ScheduleKind::GPipe, ScheduleKind::OneFOneB]);
        let par = ParallelismSpec { tp, pp: 1, dp };
        let score = |cutoff: Option<Time>| {
            let ctx = EvalContext::new(&model, &cluster).map_err(|e| format!("ctx: {e}"))?;
            SimulationBuilder::new(model.clone(), cluster.clone())
                .parallelism(par)
                .schedule(schedule)
                .fold(FoldMode::Off)
                .score_with_cutoff(&ctx, cutoff)
                .map_err(|e| format!("score({cutoff:?}) failed: {e}"))
        };
        let base = match score(None)? {
            ScoreOutcome::Complete(s) => s,
            ScoreOutcome::Cutoff => return Err("no-cutoff run reported a cutoff".into()),
        };
        let ctx = format!("{} tp={tp} dp={dp} sched={schedule:?}", cluster.name);
        for cutoff in [Some(Time::MAX), Some(base.iteration_time)] {
            let s = match score(cutoff)? {
                ScoreOutcome::Complete(s) => s,
                ScoreOutcome::Cutoff => {
                    return Err(format!("reachable run aborted at cutoff {cutoff:?}: {ctx}"))
                }
            };
            if s.iteration_time != base.iteration_time
                || s.compute_busy != base.compute_busy
                || s.comm_busy != base.comm_busy
                || s.flows_completed != base.flows_completed
                || s.events_processed != base.events_processed
            {
                return Err(format!("score diverged under cutoff {cutoff:?}: {ctx}"));
            }
        }
        if base.iteration_time > Time::ZERO {
            let below = Time::from_ps(base.iteration_time.as_ps() - 1);
            match score(Some(below))? {
                ScoreOutcome::Cutoff => {
                    aborted.fetch_add(1, Ordering::Relaxed);
                }
                ScoreOutcome::Complete(s) => {
                    return Err(format!(
                        "cutoff {below} below final clock {} did not abort: {ctx}",
                        s.iteration_time
                    ));
                }
            }
        }
        Ok(())
    });
    assert!(
        aborted.load(Ordering::Relaxed) > 0,
        "no run ever aborted on a below-final cutoff — the property is vacuous"
    );
}

#[test]
fn prop_bnb_matches_grid_best_across_thread_counts() {
    use hetsim::planner::{search, search_bnb, PlanOptions};
    use hetsim::system::fold::FoldMode;

    // bound-guided search is an optimization, not an approximation:
    // its best plan must equal the exhaustive grid's exactly, and its
    // ranked report must be byte-identical no matter how many worker
    // threads evaluated the batches (DESIGN.md §29)
    check(&cfg(3), |g| {
        let cluster = if g.rng.f64() < 0.5 {
            presets::cluster("hopper", 2).unwrap()
        } else {
            presets::cluster_hetero(1, 1).unwrap()
        };
        let mut model = presets::model("gpt-6.7b").unwrap();
        model.num_layers = g.rng.range_u64(1, 3) as u32 * 2;
        model.micro_batch = 1;
        model.global_batch = 8 * g.rng.range_u64(1, 3);
        let opts_for = |threads: usize| PlanOptions {
            microbatch_limit: Some(1),
            threads,
            refine_steps: 0,
            fold: FoldMode::Off,
        };
        let grid = search(&model, &cluster, &opts_for(1))
            .map_err(|e| format!("grid search failed: {e}"))?;
        let mut renders = Vec::new();
        for threads in [1usize, 4, 8] {
            let bnb = search_bnb(&model, &cluster, &opts_for(threads))
                .map_err(|e| format!("bnb(threads={threads}) failed: {e}"))?;
            if bnb.best().candidate != grid.best().candidate
                || bnb.best().iteration_time != grid.best().iteration_time
            {
                return Err(format!(
                    "bnb best {} @ {} != grid best {} @ {} (threads={threads})",
                    bnb.best().candidate.key(),
                    bnb.best().iteration_time,
                    grid.best().candidate.key(),
                    grid.best().iteration_time
                ));
            }
            let st = bnb.stats.ok_or("bnb report is missing search stats")?;
            if st.full_sims + st.bound_pruned + st.cutoff_aborted != st.candidates {
                return Err(format!(
                    "stats do not partition the space: {} + {} + {} != {}",
                    st.full_sims, st.bound_pruned, st.cutoff_aborted, st.candidates
                ));
            }
            renders.push((threads, bnb.render(0)));
        }
        for (threads, r) in &renders[1..] {
            if r != &renders[0].1 {
                return Err(format!(
                    "bnb report diverged between 1 and {threads} worker threads"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_resharding_trigger_matches_paper_conditions() {
    use hetsim::system::device_group::DpParticipant;
    use hetsim::system::resharding::needs_resharding;
    check(&cfg(200), |g| {
        let mk = |rng: &mut Rng, base: u32| {
            let tp = rng.range_u64(1, 5) as u32;
            DpParticipant {
                group: base,
                ranks: (base * 8..base * 8 + tp).collect(),
                tp,
                batch_share: rng.range_u64(1, 64),
                micro_batch: rng.range_u64(1, 9),
            }
        };
        let a = mk(&mut g.rng, 0);
        let b = mk(&mut g.rng, 1);
        let expect = a.tp != b.tp || a.micro_batch != b.micro_batch;
        if needs_resharding(&a, &b) != expect {
            return Err(format!("trigger mismatch: {a:?} vs {b:?}"));
        }
        Ok(())
    });
}
