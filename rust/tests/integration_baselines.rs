//! Baseline integration: the homogeneous (SimAI-like) runs bracket the
//! heterogeneous truth; the analytical (Sailor-like) estimate is in the
//! right regime; the PJRT coll_model agrees with the native mirror
//! inside the analytical baseline.

use hetsim::baselines::{analytical, homogenize};
use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::simulator::SimulationBuilder;
use hetsim::workload::aicb::WorkloadOptions;

fn opts() -> WorkloadOptions {
    WorkloadOptions { microbatch_limit: Some(1), ..Default::default() }
}

fn small_model() -> hetsim::config::model::ModelSpec {
    let mut m = presets::model("gpt-6.7b").unwrap();
    m.num_layers = 8;
    m
}

#[test]
fn homogeneous_baselines_bracket_hetero_iteration_time() {
    let model = small_model();
    let hetero_cluster = presets::cluster_hetero(1, 1).unwrap();
    let par = ParallelismSpec { tp: 8, pp: 1, dp: 2 };
    let run = |cluster| {
        SimulationBuilder::new(model.clone(), cluster)
            .parallelism(par)
            .workload_options(opts())
            .build()
            .unwrap()
            .run_iteration()
            .unwrap()
            .iteration_time
    };
    let hetero = run(hetero_cluster.clone());
    let homo_slow = run(homogenize(&hetero_cluster, 0).unwrap()); // A100 clone
    let homo_fast = run(homogenize(&hetero_cluster, 1).unwrap()); // H100 clone
    assert!(homo_fast <= hetero, "fast {homo_fast} > hetero {hetero}");
    assert!(hetero <= homo_slow, "hetero {hetero} > slow {homo_slow}");
    // the homogeneous-simulator error the paper motivates: using the
    // fast clone underestimates heterogeneous reality
    assert!(homo_fast < hetero);
}

#[test]
fn analytical_estimate_in_event_sim_regime() {
    let model = small_model();
    let cluster = presets::cluster("hopper", 1).unwrap();
    let sim = SimulationBuilder::new(model, cluster.clone())
        .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 2 })
        .workload_options(opts())
        .build()
        .unwrap();
    let event = sim.run_iteration().unwrap().iteration_time;
    let est = analytical::estimate(&sim.workload, &cluster, &sim.cost, None).unwrap();
    let ratio = event.as_secs() / est.total.as_secs();
    assert!((0.2..5.0).contains(&ratio), "event/analytical = {ratio}");
}

#[cfg(feature = "pjrt")]
#[test]
fn analytical_pjrt_backend_matches_native() {
    let model = small_model();
    let cluster = presets::cluster_hetero(1, 1).unwrap();
    let sim = SimulationBuilder::new(model, cluster.clone())
        .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
        .workload_options(opts())
        .build()
        .unwrap();
    let native = analytical::estimate(&sim.workload, &cluster, &sim.cost, None).unwrap();
    let pjrt = hetsim::runtime::PjrtCollModel::load().expect("run `make artifacts`");
    let with_pjrt =
        analytical::estimate(&sim.workload, &cluster, &sim.cost, Some(&pjrt)).unwrap();
    let rel = (native.total.as_secs() - with_pjrt.total.as_secs()).abs()
        / native.total.as_secs();
    assert!(rel < 1e-3, "native {} vs pjrt {}", native.total, with_pjrt.total);
}

#[test]
fn analytical_underestimates_under_contention() {
    // analytical ignores NIC contention between concurrent DP rings, so
    // with many rings sharing rails the event sim should be slower.
    let mut model = presets::model("gpt-6.7b").unwrap();
    model.num_layers = 2;
    let cluster = presets::cluster("ampere", 2).unwrap();
    let sim = SimulationBuilder::new(model, cluster.clone())
        .parallelism(ParallelismSpec { tp: 2, pp: 1, dp: 8 })
        .workload_options(opts())
        .build()
        .unwrap();
    let event = sim.run_iteration().unwrap().iteration_time;
    let est = analytical::estimate(&sim.workload, &cluster, &sim.cost, None).unwrap();
    assert!(
        event.as_secs() > 0.8 * est.total.as_secs(),
        "event {} far below analytical {}",
        event,
        est.total
    );
}
