//! Network-layer integration: contention, heterogeneous interconnects
//! and collective execution over the fluid flow simulator.

use hetsim::config::presets;
use hetsim::engine::Engine;
use hetsim::network::flow::{FlowId, FlowSim, FlowSpec};
use hetsim::network::topology::Topology;
use hetsim::system::collective::{
    CollectiveAlgo, CollectiveDef, CollectiveExec, CommKind, RingPolicy,
};

#[derive(Debug, Clone, Copy)]
struct Done(FlowId);

fn drive(fs: &mut FlowSim, eng: &mut Engine<Done>) -> Vec<f64> {
    let mut fcts = Vec::new();
    while let Some(ev) = eng.step() {
        if let Some(rec) = fs.on_complete(eng, ev.payload.0, ev.id, &Done) {
            fcts.push(rec.fct().as_secs());
        }
    }
    fcts
}

/// Run a collective to completion over a flow sim, returning (total
/// time, per-flow FCTs).
fn run_collective(
    cluster: &hetsim::config::cluster::ClusterSpec,
    def: &CollectiveDef,
    policy: RingPolicy,
) -> (f64, Vec<f64>) {
    let topo = Topology::build(cluster).unwrap();
    let mut fs = FlowSim::new(topo);
    let mut eng: Engine<Done> = Engine::new();
    let mut exec = CollectiveExec::plan(cluster, def, policy);
    let mut fcts = Vec::new();
    let step: Vec<FlowSpec> = exec.next_step().unwrap().to_vec();
    fs.start_many(&mut eng, &step, &Done);
    while let Some(ev) = eng.step() {
        if let Some(rec) = fs.on_complete(&mut eng, ev.payload.0, ev.id, &Done) {
            fcts.push(rec.fct().as_secs());
            if exec.flow_done() {
                if let Some(next) = exec.next_step().map(|s| s.to_vec()) {
                    fs.start_many(&mut eng, &next, &Done);
                }
            }
        }
    }
    (eng.now().as_secs(), fcts)
}

#[test]
fn intra_node_allreduce_close_to_alpha_beta_model() {
    // ring allreduce 8 ranks over NVLink: t ~= 2(n-1)/n * S / bw
    let c = presets::cluster("ampere", 1).unwrap();
    let bytes = 256u64 << 20; // 256 MiB
    let def = CollectiveDef {
        id: 0,
        algo: CollectiveAlgo::AllReduceRing,
        ranks: (0..8).collect(),
        bytes_per_rank: bytes,
        kind: CommKind::Tp,
        label: "t".into(),
    };
    let (total, fcts) = run_collective(&c, &def, RingPolicy::HeteroAware);
    assert_eq!(fcts.len(), 14 * 8);
    let bw = 300e9; // NVLink unidirectional bytes/s
    let expect = 2.0 * (7.0 / 8.0) * (bytes as f64 / bw);
    let rel = (total - expect).abs() / expect;
    assert!(rel < 0.05, "total {total} vs alpha-beta {expect} (rel {rel})");
}

#[test]
fn inter_node_allreduce_bottlenecked_by_nic() {
    let c = presets::cluster("hopper", 4).unwrap();
    let bytes = 128u64 << 20;
    // ring over local rank 0 of each node -> NIC-bound
    let def = CollectiveDef {
        id: 0,
        algo: CollectiveAlgo::AllReduceRing,
        ranks: vec![0, 8, 16, 24],
        bytes_per_rank: bytes,
        kind: CommKind::Dp,
        label: "d".into(),
    };
    let (total, _) = run_collective(&c, &def, RingPolicy::HeteroAware);
    let nic = 25e9;
    let expect = 2.0 * (3.0 / 4.0) * (bytes as f64 / nic);
    let rel = (total - expect).abs() / expect;
    assert!(rel < 0.05, "total {total} vs {expect} (rel {rel})");
}

#[test]
fn hetero_ring_no_slower_than_slowest_homogeneous_intra_node() {
    let bytes = 64u64 << 20;
    let mk = |cluster: &hetsim::config::cluster::ClusterSpec, ranks: Vec<u32>| {
        let def = CollectiveDef {
            id: 0,
            algo: CollectiveAlgo::AllReduceRing,
            ranks,
            bytes_per_rank: bytes,
            kind: CommKind::Tp,
            label: "t".into(),
        };
        run_collective(cluster, &def, RingPolicy::HeteroAware).0
    };
    let ampere = mk(&presets::cluster("ampere", 1).unwrap(), (0..8).collect());
    let hopper = mk(&presets::cluster("hopper", 1).unwrap(), (0..8).collect());
    // hetero cluster, intra-node ring on the ampere node = ampere time
    let hetero = mk(&presets::cluster_hetero(1, 1).unwrap(), (0..8).collect());
    assert!(hopper < ampere);
    let rel = (hetero - ampere).abs() / ampere;
    assert!(rel < 0.02, "hetero {hetero} vs ampere {ampere}");
}

#[test]
fn hetero_aware_ring_beats_naive_on_mixed_ring() {
    // ring spanning both architectures with fully interleaved rank
    // order: node-major reordering turns most ring edges intra-node
    // (NVLink) and removes NIC contention between same-rail flows
    let c = presets::cluster_hetero(2, 2).unwrap();
    let ranks: Vec<u32> = (0..32).map(|i| (i % 4) * 8 + i / 4).collect();
    let def = CollectiveDef {
        id: 0,
        algo: CollectiveAlgo::AllReduceRing,
        ranks,
        bytes_per_rank: 256 << 20,
        kind: CommKind::Dp,
        label: "d".into(),
    };
    let (naive, _) = run_collective(&c, &def, RingPolicy::Naive);
    let (aware, _) = run_collective(&c, &def, RingPolicy::HeteroAware);
    // Finding (EXPERIMENTS.md): on rail-only topologies the fluid model
    // shows the rail design absorbs bad orderings almost entirely —
    // hetero-aware ordering must simply never be worse.
    assert!(aware <= naive * 1.001, "aware {aware} worse than naive {naive}");
}

#[test]
fn contention_slows_sharing_flows() {
    let c = presets::cluster("ampere", 2).unwrap();
    let topo = Topology::build(&c).unwrap();
    let mut fs = FlowSim::new(topo);
    let mut eng: Engine<Done> = Engine::new();
    // 4 flows over the same rail vs 1 flow: per-flow FCT ~4x
    let bytes = 25_000_000_00u64; // 0.1 s alone
    let specs: Vec<FlowSpec> =
        (0..4).map(|i| FlowSpec { src: 7, dst: 15, bytes, tag: i }).collect();
    fs.start_many(&mut eng, &specs, &Done);
    let fcts = drive(&mut fs, &mut eng);
    for f in &fcts {
        assert!((f - 0.4).abs() < 0.01, "fct {f}");
    }
}

#[test]
fn hierarchical_beats_flat_ring_across_nodes() {
    // 2 nodes x 8 GPUs, allreduce over all 16: hierarchical (NVLink
    // intra + per-rail inter) should beat a flat ring that crosses the
    // NIC 16 times.
    let c = presets::cluster("hopper", 2).unwrap();
    let bytes = 64u64 << 20;
    let flat = CollectiveDef {
        id: 0,
        algo: CollectiveAlgo::AllReduceRing,
        ranks: (0..16).collect(),
        bytes_per_rank: bytes,
        kind: CommKind::Dp,
        label: "flat".into(),
    };
    let hier = CollectiveDef {
        id: 1,
        algo: CollectiveAlgo::AllReduceHierarchical,
        ranks: (0..16).collect(),
        bytes_per_rank: bytes,
        kind: CommKind::Dp,
        label: "hier".into(),
    };
    let (t_flat, _) = run_collective(&c, &flat, RingPolicy::HeteroAware);
    let (t_hier, _) = run_collective(&c, &hier, RingPolicy::HeteroAware);
    assert!(t_hier < t_flat, "hier {t_hier} >= flat {t_flat}");
}

#[test]
fn fct_records_tagged_for_distribution_analysis() {
    let c = presets::cluster("ampere", 2).unwrap();
    let def = CollectiveDef {
        id: 42,
        algo: CollectiveAlgo::AllGather,
        ranks: vec![0, 8],
        bytes_per_rank: 1 << 20,
        kind: CommKind::Dp,
        label: "d".into(),
    };
    let topo = Topology::build(&c).unwrap();
    let mut fs = FlowSim::new(topo);
    let mut eng: Engine<Done> = Engine::new();
    let mut exec = CollectiveExec::plan(&c, &def, RingPolicy::HeteroAware);
    let step: Vec<FlowSpec> = exec.next_step().unwrap().to_vec();
    fs.start_many(&mut eng, &step, &Done);
    drive(&mut fs, &mut eng);
    assert!(fs.records.iter().all(|r| r.tag == 42));
}
