//! PJRT round-trip integration: the AOT artifacts (JAX Layer-2 graph +
//! Pallas Layer-1 kernels, lowered to HLO text) must load, execute, and
//! agree with the native Rust mirror row-for-row.
//!
//! Requires `make artifacts`, adding the `xla` crate to
//! rust/Cargo.toml, and building with `--features pjrt` (the default
//! build deliberately omits the dependency and ships stub PJRT
//! models; see `runtime` and DESIGN.md §4).
#![cfg(feature = "pjrt")]

use hetsim::compute::cost::{LayerWork, NativeCostModel};
use hetsim::compute::table::{CostEvaluator, CostTable};
use hetsim::config::model::LayerKind;
use hetsim::config::presets;
use hetsim::runtime::{artifacts_dir, PjrtCollModel, PjrtCostModel, Runtime};

fn work(kind: LayerKind, mbs: f64, tp: f64, is_bwd: bool) -> LayerWork {
    LayerWork {
        kind,
        hidden: 4096.0,
        ffn: 16384.0,
        heads: 32.0,
        seq: 2048.0,
        mbs,
        n_experts: if kind == LayerKind::Moe { 8.0 } else { 0.0 },
        top_k: if kind == LayerKind::Moe { 2.0 } else { 0.0 },
        tp,
        is_bwd,
    }
}

#[test]
fn pjrt_client_boots() {
    let rt = Runtime::cpu().unwrap();
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
}

#[test]
fn artifacts_exist() {
    let dir = artifacts_dir().expect("run `make artifacts` before cargo test");
    assert!(dir.join("cost_model.hlo.txt").exists());
    assert!(dir.join("coll_model.hlo.txt").exists());
    assert!(dir.join("manifest.json").exists());
}

#[test]
fn cost_artifact_matches_native_mirror() {
    // every layer kind x gpu x fwd/bwd x a few tp/mbs combinations
    let mut pjrt = PjrtCostModel::load().expect("run `make artifacts`");
    let native = NativeCostModel;
    let gpus = [presets::gpu("A100").unwrap(), presets::gpu("H100").unwrap()];
    let mut layers = Vec::new();
    let mut gpu_rows = Vec::new();
    let mut expected = Vec::new();
    for gpu in &gpus {
        for kind in [
            LayerKind::Embedding,
            LayerKind::Attention,
            LayerKind::Mlp,
            LayerKind::Moe,
            LayerKind::Other,
        ] {
            for (mbs, tp, bwd) in [(1.0, 1.0, false), (8.0, 4.0, false), (8.0, 8.0, true)] {
                let w = work(kind, mbs, tp, bwd);
                layers.push(w.descriptor_row());
                gpu_rows.push(gpu.descriptor_row());
                expected.push(native.time_seconds(&w, gpu));
            }
        }
    }
    let got = pjrt.evaluate_batch(&layers, &gpu_rows).unwrap();
    assert_eq!(got.len(), expected.len());
    for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
        let rel = ((*g as f64) - e).abs() / e.max(1e-12);
        assert!(rel < 1e-3, "row {i}: pjrt={g} native={e} rel={rel} ({:?})", layers[i]);
    }
}

#[test]
fn cost_table_with_pjrt_backend() {
    let pjrt = PjrtCostModel::load().expect("run `make artifacts`");
    let mut table = CostTable::new(Box::new(pjrt));
    let gpu = presets::gpu("H100").unwrap();
    let w = work(LayerKind::Mlp, 8.0, 1.0, false);
    table.register(&w, &gpu);
    table.evaluate().unwrap();
    let t_pjrt = table.time(&w, &gpu).unwrap().as_secs();
    let t_native = NativeCostModel.time_seconds(&w, &gpu);
    assert!((t_pjrt - t_native).abs() / t_native < 1e-3);
}

#[test]
fn coll_artifact_matches_native_mirror() {
    let model = PjrtCollModel::load().expect("run `make artifacts`");
    let rows: Vec<[f32; 8]> = vec![
        [0.0, 8.0, 1e9, 25e9, 1e-6, 0.0, 0.0, 0.0],
        [1.0, 16.0, 5e8, 300e9, 2e-7, 2.0, 0.0, 0.0],
        [3.0, 4.0, 1e7, 25e9, 1e-6, 0.0, 0.0, 0.0],
        [4.0, 32.0, 1e9, 25e9, 1e-6, 1.0, 0.0, 0.0],
        [5.0, 2.0, 1e9, 1e10, 5e-6, 0.0, 0.0, 0.0],
    ];
    let got = model.evaluate(&rows).unwrap();
    for (row, g) in rows.iter().zip(&got) {
        let e = hetsim::baselines::analytical::coll_time_native(row);
        let rel = ((*g as f64) - e).abs() / e.max(1e-12);
        assert!(rel < 1e-3, "row {row:?}: pjrt={g} native={e}");
    }
}

#[test]
fn fig5_identical_under_both_backends() {
    let mut native = CostTable::native();
    let rows_native = hetsim::report::fig5::compute(&mut native).unwrap();
    let pjrt = PjrtCostModel::load().expect("run `make artifacts`");
    let mut pjrt_table = CostTable::new(Box::new(pjrt));
    let rows_pjrt = hetsim::report::fig5::compute(&mut pjrt_table).unwrap();
    for (a, b) in rows_native.iter().zip(&rows_pjrt) {
        assert_eq!(a.layer, b.layer);
        let rel = (a.h100_ms - b.h100_ms).abs() / a.h100_ms;
        assert!(rel < 1e-3, "{} {}: {} vs {}", a.model, a.layer, a.h100_ms, b.h100_ms);
        let rel_deg = (a.degradation - b.degradation).abs() / a.degradation;
        assert!(rel_deg < 1e-3);
    }
}
