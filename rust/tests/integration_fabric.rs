//! Fabric-layer integration (DESIGN.md §24): mixed per-node GPU counts
//! simulate end-to-end, rank mapping agrees with
//! `ClusterSpec::node_of_rank`, oversubscribed leaf/spine fabrics slow
//! DP traffic, and the rail-only default stays route-compatible.

use hetsim::config::cluster::{ClusterSpec, FabricSpec};
use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::network::routing;
use hetsim::network::topology::Topology;
use hetsim::planner::{search, PlanOptions};
use hetsim::simulator::SimulationBuilder;
use hetsim::workload::aicb::WorkloadOptions;
use hetsim::workload::partition::plan_variable_tp;

fn mixed_cluster() -> ClusterSpec {
    // one 4-GPU A100 node beside one 8-GPU H100 node
    let mut c = presets::cluster_hetero(1, 1).unwrap();
    c.nodes[0].gpus_per_node = 4;
    c
}

fn tiny_model() -> hetsim::config::model::ModelSpec {
    let mut m = presets::model("gpt-6.7b").unwrap();
    m.num_layers = 4;
    m.global_batch = 16;
    m.micro_batch = 8;
    m
}

#[test]
fn topology_rank_mapping_agrees_with_cluster_on_every_fabric() {
    for fabric in [
        FabricSpec::RailOnly,
        FabricSpec::SingleSwitch,
        FabricSpec::LeafSpine { spines: 3, oversubscription: 2.0 },
    ] {
        let mut c = mixed_cluster();
        c.fabric = fabric;
        let t = Topology::build(&c).unwrap();
        assert_eq!(t.total_gpus(), c.total_gpus());
        for rank in 0..t.total_gpus() {
            let (node, local) = t.locate(rank);
            assert_eq!(Some(node), c.node_of_rank(rank), "{fabric:?} rank {rank}");
            assert_eq!(Some((node, local)), c.locate(rank));
            assert_eq!(t.rank_of(node, local), rank);
        }
    }
}

#[test]
fn mixed_node_sizes_simulate_end_to_end() {
    // explicit per-node TP splits matching each node's GPU count
    let m = tiny_model();
    let c = mixed_cluster();
    let fw = plan_variable_tp(&m, &c, &[vec![4], vec![4, 4]], true).unwrap();
    let rep = SimulationBuilder::new(m, c)
        .framework(fw)
        .workload_options(WorkloadOptions { microbatch_limit: Some(1), ..Default::default() })
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    assert!(rep.iteration_time > hetsim::util::units::Time::ZERO);
    assert!(rep.flows_completed > 0);
    assert!(rep.fct_summary.contains_key("DP"));
}

#[test]
fn mixed_node_sizes_default_parallelism_simulates() {
    // no explicit plan: infer_parallelism picks the node-size GCD
    let m = tiny_model();
    let c = mixed_cluster();
    let par = hetsim::simulator::infer_parallelism(&m, &c).unwrap();
    assert_eq!(par.tp, 4, "GCD of 4 and 8");
    assert_eq!(par.world_size(), 12);
    let rep = SimulationBuilder::new(m, c)
        .workload_options(WorkloadOptions { microbatch_limit: Some(1), ..Default::default() })
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    assert!(rep.iteration_time > hetsim::util::units::Time::ZERO);
}

#[test]
fn oversubscribed_leaf_spine_slows_dp_allreduce() {
    // acceptance: DP gradient sync on a 4:1-oversubscribed leaf/spine
    // fabric takes strictly longer than on the non-oversubscribed one
    let run = |oversubscription: f64| {
        let mut c = presets::cluster("hopper", 2).unwrap();
        c.fabric = FabricSpec::LeafSpine { spines: 2, oversubscription };
        let rep = SimulationBuilder::new(tiny_model(), c)
            .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
            .workload_options(WorkloadOptions {
                microbatch_limit: Some(1),
                ..Default::default()
            })
            .build()
            .unwrap()
            .run_iteration()
            .unwrap();
        let dp: f64 = rep.fct_by_kind["DP"].sum();
        (rep.iteration_time, dp)
    };
    let (t1, dp1) = run(1.0);
    let (t4, dp4) = run(4.0);
    assert!(dp4 > dp1, "DP FCT sum did not grow: {dp4} <= {dp1}");
    assert!(t4 > t1, "iteration did not slow down: {t4} <= {t1}");
}

#[test]
fn single_switch_matches_rail_on_same_rail_traffic_and_beats_it_cross_rail() {
    // same-rail inter-node routes are 4 hops on both fabrics; the
    // cross-rail case drops the 2 NVLink detour hops on the switch
    let mk = |fabric| {
        let mut c = presets::cluster("hopper", 2).unwrap();
        c.fabric = fabric;
        Topology::build(&c).unwrap()
    };
    let rail = mk(FabricSpec::RailOnly);
    let switch = mk(FabricSpec::SingleSwitch);
    assert_eq!(routing::route(&rail, 7, 15).hops(), 4);
    assert_eq!(routing::route(&switch, 7, 15).hops(), 4);
    assert_eq!(routing::route(&rail, 7, 8).hops(), 6);
    assert_eq!(routing::route(&switch, 7, 8).hops(), 4);
}

#[test]
fn fabrics_simulate_end_to_end_and_stay_deterministic() {
    for fabric in [
        FabricSpec::SingleSwitch,
        FabricSpec::LeafSpine { spines: 2, oversubscription: 2.0 },
    ] {
        let run = || {
            let mut c = presets::cluster_hetero(1, 1).unwrap();
            c.fabric = fabric;
            SimulationBuilder::new(tiny_model(), c)
                .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
                .workload_options(WorkloadOptions {
                    microbatch_limit: Some(1),
                    ..Default::default()
                })
                .build()
                .unwrap()
                .run_iteration()
                .unwrap()
        };
        let a = run();
        let b = run();
        assert!(a.iteration_time > hetsim::util::units::Time::ZERO, "{fabric:?}");
        assert_eq!(a.iteration_time, b.iteration_time, "{fabric:?}");
        assert_eq!(a.events_processed, b.events_processed, "{fabric:?}");
    }
}

#[test]
fn tp_allreduce_algo_follows_fabric_in_generated_workloads() {
    // a regular cross-node TP group (16 ranks, 8 per node): flat ring
    // on rail-only (the seed default), hierarchical on the switch
    use hetsim::config::framework::FrameworkSpec;
    use hetsim::system::collective::{CollectiveAlgo, CommKind};
    let m = tiny_model();
    let mk = |fabric| {
        let mut c = presets::cluster("hopper", 2).unwrap();
        c.fabric = fabric;
        let fw =
            FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 16, pp: 1, dp: 1 }).unwrap();
        hetsim::workload::aicb::generate(
            &m,
            &c,
            &fw,
            &WorkloadOptions { microbatch_limit: Some(1), ..Default::default() },
        )
        .unwrap()
    };
    let rail = mk(FabricSpec::RailOnly);
    let switch = mk(FabricSpec::SingleSwitch);
    let tp_algos = |w: &hetsim::workload::op::Workload| -> Vec<CollectiveAlgo> {
        w.collectives.iter().filter(|c| c.kind == CommKind::Tp).map(|c| c.algo).collect()
    };
    let rail_tp = tp_algos(&rail);
    let switch_tp = tp_algos(&switch);
    assert!(!rail_tp.is_empty());
    assert!(rail_tp.iter().all(|a| *a == CollectiveAlgo::AllReduceRing));
    assert_eq!(switch_tp.len(), rail_tp.len());
    assert!(switch_tp.iter().all(|a| *a == CollectiveAlgo::AllReduceHierarchical));
}

#[test]
fn plan_search_covers_mixed_sizes_on_leaf_spine() {
    // the CI smoke scenario as a test: candidate enumeration, scoring
    // and ranking all work on a mixed-node-size leaf/spine cluster
    let m = tiny_model();
    let mut c = mixed_cluster();
    c.fabric = FabricSpec::LeafSpine { spines: 2, oversubscription: 4.0 };
    let opts = PlanOptions { microbatch_limit: Some(1), threads: 2, refine_steps: 0, ..Default::default() };
    let rep = search(&m, &c, &opts).unwrap();
    assert!(!rep.ranked.is_empty());
    assert!(rep.failed.is_empty(), "{:?}", rep.failed);
    // variable per-node layouts (the only node-aligned shapes for
    // mixed sizes) are part of the ranked space
    assert!(rep
        .ranked
        .iter()
        .any(|ev| matches!(ev.candidate.layout, hetsim::planner::TpLayout::PerNode(_))));
    // ranking is deterministic across worker counts here too
    let again = search(&m, &c, &PlanOptions { threads: 4, ..opts.clone() }).unwrap();
    assert_eq!(rep.render(0), again.render(0));
}
