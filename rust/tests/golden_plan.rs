//! Golden determinism suite for the planner hot path.
//!
//! Every optimization of the evaluation pipeline (shared `EvalContext`,
//! slab event queue, scoped flow rebalance) must be provably
//! behavior-preserving. Two layers of enforcement:
//!
//! 1. **Cross-thread identity** (always on): full rendered plan reports
//!    for the hetero:1,1 and Fig-3 ladders are byte-identical across
//!    1/4/8 worker threads, and the context-sharing build path produces
//!    bit-identical reports to the plain per-candidate build path.
//! 2. **Golden fingerprints** (self-bootstrapping): the first run
//!    records each rendered report under `tests/golden/`; subsequent
//!    runs compare byte-for-byte. Commit the recorded files so future
//!    perf work diffs against them; if a behavior change is
//!    *intentional*, delete the stale file and rerun to re-record.

use std::fs;
use std::path::PathBuf;

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::model::ModelSpec;
use hetsim::config::presets;
use hetsim::planner::{enumerate, search, PlanOptions};
use hetsim::simulator::{EvalContext, SimulationBuilder};
use hetsim::workload::aicb::WorkloadOptions;
use hetsim::workload::partition::{fig3_cluster, fig3_model};

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

/// Compare `content` against the committed golden file, or record it on
/// first run (bootstrap).
fn check_golden(name: &str, content: &str) {
    let path = golden_dir().join(name);
    if path.exists() {
        let want = fs::read_to_string(&path).unwrap();
        assert_eq!(
            want,
            content,
            "golden fingerprint {} drifted — perf work must be behavior-preserving. \
             If this change is intentional, delete the file and rerun to re-record.",
            path.display()
        );
    } else {
        fs::create_dir_all(golden_dir()).unwrap();
        fs::write(&path, content).unwrap();
        eprintln!(
            "recorded golden fingerprint {} — commit it to pin this behavior",
            path.display()
        );
    }
}

fn tiny_model() -> ModelSpec {
    let mut m = presets::model("gpt-6.7b").unwrap();
    m.num_layers = 4;
    m.global_batch = 16;
    m.micro_batch = 8;
    m
}

#[test]
fn hetero_plan_report_golden_and_thread_invariant() {
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let render = |threads| {
        let opts =
            PlanOptions { microbatch_limit: Some(1), threads, refine_steps: 2, ..Default::default() };
        search(&m, &c, &opts).unwrap().render(0)
    };
    let one = render(1);
    for threads in [4, 8] {
        assert_eq!(one, render(threads), "threads={threads}");
    }
    check_golden("plan_hetero_1_1.txt", &one);
}

#[test]
fn fig3_plan_report_golden_and_thread_invariant() {
    // quick Fig-3 ladder (microbatch-capped; the full-batch acceptance
    // run lives in integration_planner.rs) — exercises the
    // memory-relaxed fallback and the variable per-group TP layouts
    let m = fig3_model().unwrap();
    let c = fig3_cluster().unwrap();
    let render = |threads| {
        let opts =
            PlanOptions { microbatch_limit: Some(1), threads, refine_steps: 2, ..Default::default() };
        search(&m, &c, &opts).unwrap().render(0)
    };
    let one = render(1);
    for threads in [4, 8] {
        assert_eq!(one, render(threads), "threads={threads}");
    }
    assert!(one.contains("memory"), "fig3 must surface the memory relaxation:\n{one}");
    check_golden("plan_fig3.txt", &one);
}

#[test]
fn context_scores_match_plain_builds_for_every_candidate_kind() {
    // the zero-rebuild path (shared EvalContext) must be bit-identical
    // to a cold per-candidate build across the whole candidate space
    let m = tiny_model();
    let c = presets::cluster_hetero(1, 1).unwrap();
    let (candidates, _) = enumerate(&m, &c, Some(1));
    assert!(candidates.len() >= 8);
    let ctx = EvalContext::new(&m, &c).unwrap();
    // a representative slice: first few + every variable-TP layout
    let picks: Vec<_> = candidates
        .iter()
        .take(4)
        .chain(candidates.iter().filter(|cand| {
            matches!(cand.layout, hetsim::planner::TpLayout::PerNode(_))
        }))
        .take(8)
        .collect();
    for cand in picks {
        let fw = cand.framework(&m, &c).unwrap();
        let mk = || {
            SimulationBuilder::new(m.clone(), c.clone())
                .parallelism(cand.par)
                .framework(fw.clone())
                .ring_policy(cand.ring)
                .workload_options(WorkloadOptions {
                    microbatch_limit: Some(1),
                    ..Default::default()
                })
        };
        let plain = mk().build().unwrap().run_iteration().unwrap();
        let score = mk().score_with_context(&ctx).unwrap();
        assert_eq!(plain.iteration_time, score.iteration_time, "{}", cand.key());
        assert_eq!(plain.events_processed, score.events_processed, "{}", cand.key());
        assert_eq!(plain.flows_completed, score.flows_completed, "{}", cand.key());
        assert_eq!(plain.compute_busy, score.compute_busy, "{}", cand.key());
        assert_eq!(plain.comm_busy, score.comm_busy, "{}", cand.key());
        // scoring twice is a cache hit with the same result
        let again = mk().score_with_context(&ctx).unwrap();
        assert_eq!(score.iteration_time, again.iteration_time);
    }
    assert!(ctx.score_cache_hits() > 0, "revisited specs must hit the score cache");
}

#[test]
fn simulate_timeline_golden() {
    // a plain (non-planner) simulation fingerprint: pins the engine +
    // flow-simulator timeline through the queue/rebalance rework
    let rep = SimulationBuilder::new(tiny_model(), presets::cluster_hetero(1, 1).unwrap())
        .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    let fingerprint = format!(
        "iteration_ps={}\nevents={}\nflows={}\ncompute_busy_ps={}\ncomm_busy_ps={}\n",
        rep.iteration_time.as_ps(),
        rep.events_processed,
        rep.flows_completed,
        rep.compute_busy.as_ps(),
        rep.comm_busy.as_ps(),
    );
    check_golden("simulate_hetero_1_1.txt", &fingerprint);
}

#[test]
fn scenario_faults_timeline_golden() {
    // the committed fault scenario (straggler from t=0 plus a node loss
    // 0.5s into the iteration) pins the fault-injection timeline: the
    // abort point, the event count at the abort, and the lost-work
    // accounting must all survive perf work (DESIGN.md §26)
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("examples").join("scenario_faults.json");
    let text = fs::read_to_string(&path).unwrap();
    let s = hetsim::config::loader::load_scenario(&text).unwrap();
    assert!(s.faults.is_some(), "scenario_faults.json must carry a fault spec");
    let rep = SimulationBuilder::new(s.model, s.cluster)
        .parallelism(s.parallelism)
        .schedule(s.schedule)
        .fold(s.fold)
        .faults(s.faults)
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    let fault = rep.fault.expect("the 0.5s node_fail must abort the iteration");
    assert_eq!(rep.iteration_time, fault.at, "the clock must stop at the fault");
    let fingerprint = format!(
        "iteration_ps={}\nevents={}\nflows={}\ncompute_busy_ps={}\ncomm_busy_ps={}\n\
         fault_node={}\nfault_at_ps={}\nlost_work_ps={}\n",
        rep.iteration_time.as_ps(),
        rep.events_processed,
        rep.flows_completed,
        rep.compute_busy.as_ps(),
        rep.comm_busy.as_ps(),
        fault.node,
        fault.at.as_ps(),
        fault.lost_work.as_ps(),
    );
    check_golden("simulate_scenario_faults.txt", &fingerprint);
}

#[test]
fn simulate_fold_off_matches_seed_golden() {
    // fold=off must be byte-identical to the pre-folding engine: an
    // explicit `.fold(FoldMode::Off)` build reproduces the SAME
    // fingerprint as the default build (every count, not just the
    // times), and both pin the golden `simulate_timeline_golden` uses
    use hetsim::system::fold::FoldMode;
    let fingerprint = |explicit_off: bool| {
        let mut b = SimulationBuilder::new(tiny_model(), presets::cluster_hetero(1, 1).unwrap())
            .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 });
        if explicit_off {
            b = b.fold(FoldMode::Off);
        }
        let rep = b.build().unwrap().run_iteration().unwrap();
        format!(
            "iteration_ps={}\nevents={}\nflows={}\ncompute_busy_ps={}\ncomm_busy_ps={}\n",
            rep.iteration_time.as_ps(),
            rep.events_processed,
            rep.flows_completed,
            rep.compute_busy.as_ps(),
            rep.comm_busy.as_ps(),
        )
    };
    let default_build = fingerprint(false);
    let fold_off = fingerprint(true);
    assert_eq!(default_build, fold_off, "fold=off diverged from the default build");
    check_golden("simulate_hetero_1_1.txt", &fold_off);
}
