//! System-layer integration: the Fig-3 resharding scenario end-to-end,
//! non-uniform partitioning, and report generation.

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::simulator::SimulationBuilder;
use hetsim::system::collective::CommKind;
use hetsim::workload::aicb::WorkloadOptions;
use hetsim::workload::partition::{fig3_cluster, fig3_model, fig3_plan, plan_hetero};

#[test]
fn fig3_scenario_end_to_end_with_resharding() {
    let model = fig3_model().unwrap();
    let cluster = fig3_cluster().unwrap();
    let plan = fig3_plan(&model, &cluster).unwrap();
    let sim = SimulationBuilder::new(model, cluster).framework(plan).build().unwrap();
    // resharding collectives were injected
    let reshard =
        sim.workload.collectives.iter().filter(|c| c.kind == CommKind::Reshard).count();
    assert!(reshard > 0, "fig3 must trigger resharding");
    let rep = sim.run_iteration().unwrap();
    assert!(rep.fct_summary.contains_key("RESHARD"));
    assert!(rep.iteration_time.as_secs() > 0.0);
}

#[test]
fn uniform_tp_same_cluster_avoids_resharding() {
    let model = fig3_model().unwrap();
    let cluster = fig3_cluster().unwrap();
    let sim = SimulationBuilder::new(model, cluster)
        .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 2 })
        .build()
        .unwrap();
    let reshard =
        sim.workload.collectives.iter().filter(|c| c.kind == CommKind::Reshard).count();
    assert_eq!(reshard, 0, "uniform TP must not reshard");
}

#[test]
fn hetero_partitioner_full_pipeline() {
    let mut model = presets::model("gpt-6.7b").unwrap();
    model.num_layers = 8;
    model.global_batch = 64;
    model.micro_batch = 4;
    let cluster = presets::cluster_hetero(1, 1).unwrap();
    let fw = plan_hetero(&model, &cluster, ParallelismSpec { tp: 8, pp: 1, dp: 2 }).unwrap();
    // group on the hopper node gets more batch
    assert!(fw.groups[1].batch_share > fw.groups[0].batch_share);
    let rep = SimulationBuilder::new(model, cluster)
        .framework(fw)
        .workload_options(WorkloadOptions { microbatch_limit: Some(2), ..Default::default() })
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    assert!(rep.flows_completed > 0);
}

#[test]
fn pipeline_layer_imbalance_shifts_work() {
    // hetero pipeline across an ampere and a hopper node: the planner
    // gives the hopper stage more layers, and the resulting iteration
    // beats the uniform split.
    let mut model = presets::model("llama2-70b").unwrap();
    model.global_batch = 4;
    model.micro_batch = 1;
    let cluster = presets::cluster_hetero(1, 1).unwrap();
    let uniform = SimulationBuilder::new(model.clone(), cluster.clone())
        .parallelism(ParallelismSpec { tp: 8, pp: 2, dp: 1 })
        .workload_options(WorkloadOptions { microbatch_limit: Some(2), ..Default::default() })
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    let fw = plan_hetero(&model, &cluster, ParallelismSpec { tp: 8, pp: 2, dp: 1 }).unwrap();
    let planned = SimulationBuilder::new(model, cluster)
        .framework(fw)
        .workload_options(WorkloadOptions { microbatch_limit: Some(2), ..Default::default() })
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    assert!(
        planned.iteration_time < uniform.iteration_time,
        "planned {} >= uniform {}",
        planned.iteration_time,
        uniform.iteration_time
    );
}

#[test]
fn fig5_report_generates() {
    let mut table = hetsim::compute::table::CostTable::native();
    let rows = hetsim::report::fig5::compute(&mut table).unwrap();
    let t = hetsim::report::fig5::render(&rows);
    assert!(t.markdown().contains("A100/H100"));
}

#[test]
fn fig6_cell_hetero_tail_amplification() {
    use hetsim::report::fig6::{run_cell, ClusterKind};
    let ampere = run_cell("gpt-6.7b", ClusterKind::Ampere, 2, Some(1)).unwrap();
    let hetero = run_cell("gpt-6.7b", ClusterKind::Hetero5050, 2, Some(1)).unwrap();
    // paper Q2: hetero tail >= slow-homogeneous tail is NOT guaranteed,
    // but hetero must not beat the fast-homogeneous tail
    let hopper = run_cell("gpt-6.7b", ClusterKind::Hopper, 2, Some(1)).unwrap();
    assert!(hetero.p999_us >= hopper.p999_us);
    assert!(ampere.p999_us > 0.0);
}

#[test]
fn trace_recording_captures_compute_and_comm() {
    let mut model = presets::model("gpt-6.7b").unwrap();
    model.num_layers = 2;
    model.global_batch = 8;
    model.micro_batch = 8;
    let rep = SimulationBuilder::new(model, presets::cluster("hopper", 1).unwrap())
        .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 2 })
        .record_trace(true)
        .build()
        .unwrap()
        .run_iteration()
        .unwrap();
    assert!(rep.compute_busy.as_secs() > 0.0);
    assert!(rep.comm_busy.as_secs() > 0.0);
}
