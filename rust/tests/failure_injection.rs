//! Failure-injection tests: corrupted workloads, malformed configs and
//! hostile inputs must produce clear errors, never wrong results or
//! hangs.

use hetsim::compute::table::CostTable;
use hetsim::config::framework::{FrameworkSpec, ParallelismSpec};
use hetsim::config::presets;
use hetsim::system::scheduler::Scheduler;
use hetsim::workload::aicb::{generate, register_costs, WorkloadOptions};
use hetsim::workload::op::{Op, Workload};

fn small_setup() -> (hetsim::config::cluster::ClusterSpec, Workload, CostTable) {
    let mut m = presets::model("gpt-6.7b").unwrap();
    m.num_layers = 2;
    m.global_batch = 8;
    m.micro_batch = 4;
    let c = presets::cluster("hopper", 1).unwrap();
    let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 4, pp: 1, dp: 2 }).unwrap();
    let w = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
    let mut t = CostTable::native();
    register_costs(&w, &c, &mut t).unwrap();
    (c, w, t)
}

#[test]
fn dangling_recv_is_a_deadlock_error_not_a_hang() {
    let (c, mut w, t) = small_setup();
    // inject a recv that will never be satisfied
    w.programs[0].ops.push(Op::Recv { msg: 999_999 });
    let err = Scheduler::new(&w, &c, &t).unwrap().run().unwrap_err();
    assert!(err.to_string().contains("deadlock"), "{err}");
}

#[test]
fn missing_collective_participant_deadlocks_cleanly() {
    let (c, mut w, t) = small_setup();
    // drop one rank's participation in the first TP collective
    let def_id = w.collectives[0].id;
    let victim = w.collectives[0].ranks[0];
    let prog = w.programs.iter_mut().find(|p| p.rank == victim).unwrap();
    let pos = prog
        .ops
        .iter()
        .position(|op| matches!(op, Op::Collective { def_id: d } if *d == def_id))
        .unwrap();
    prog.ops.remove(pos);
    // validation catches it up front
    assert!(w.validate().is_err());
    // and even if validation were skipped, the run terminates with a
    // deadlock diagnosis rather than hanging
    let err = Scheduler::new(&w, &c, &t).unwrap().run().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("deadlock") || msg.contains("collective"), "{msg}");
}

#[test]
fn unregistered_cost_pair_reports_table_miss() {
    let (c, w, _) = small_setup();
    let empty = CostTable::native(); // never evaluated
    let err = Scheduler::new(&w, &c, &empty).unwrap().run().unwrap_err();
    assert!(err.to_string().contains("cost table miss"), "{err}");
}

#[test]
fn rank_outside_cluster_rejected() {
    let (c, mut w, t) = small_setup();
    w.programs[0].rank = 500; // beyond the 8-GPU cluster
    let err = Scheduler::new(&w, &c, &t).unwrap().run().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("outside cluster") || msg.contains("no program"), "{msg}");
}

#[test]
fn corrupt_trace_files_rejected_with_context() {
    for (text, needle) in [
        ("", "header"),
        ("{\"type\":\"header\",\"version\":9}", "version"),
        ("{\"type\":\"header\",\"version\":1}\n{\"type\":\"op\",\"rank\":0,\"op\":\"fly\"}", "line 2"),
        ("{\"type\":\"header\",\"version\":1}\n{\"type\":\"mystery\"}", "line 2"),
    ] {
        let err = hetsim::workload::parser::parse(text).unwrap_err();
        assert!(err.to_string().contains(needle), "{text:?} -> {err}");
    }
}

#[test]
fn malformed_scenario_files_rejected() {
    for text in [
        "not json at all",
        "{\"model\": \"gpt-6.7b\"}",                        // missing keys
        "{\"model\": 42, \"cluster\": \"ampere:1\", \"parallelism\": {\"tp\":1,\"pp\":1,\"dp\":8}}",
        "{\"model\": \"gpt-6.7b\", \"cluster\": \"warp:2\", \"parallelism\": {\"tp\":1,\"pp\":1,\"dp\":8}}",
    ] {
        assert!(hetsim::config::loader::load_scenario(text).is_err(), "{text}");
    }
}

#[test]
fn zero_byte_and_single_rank_collectives_complete() {
    // degenerate collectives must not wedge the scheduler
    use hetsim::system::collective::{CollectiveAlgo, CollectiveDef, CommKind};
    use hetsim::workload::op::RankProgram;
    let c = presets::cluster("hopper", 1).unwrap();
    let w = Workload {
        programs: vec![
            RankProgram { rank: 0, ops: vec![Op::Collective { def_id: 0 }, Op::Collective { def_id: 1 }] },
            RankProgram { rank: 1, ops: vec![Op::Collective { def_id: 1 }] },
        ],
        collectives: vec![
            CollectiveDef {
                id: 0,
                algo: CollectiveAlgo::AllReduceRing,
                ranks: vec![0],
                bytes_per_rank: 1 << 20,
                kind: CommKind::Tp,
                label: "single".into(),
            },
            CollectiveDef {
                id: 1,
                algo: CollectiveAlgo::AllGather,
                ranks: vec![0, 1],
                bytes_per_rank: 0,
                kind: CommKind::Tp,
                label: "empty".into(),
            },
        ],
    };
    let t = CostTable::native();
    let rep = Scheduler::new(&w, &c, &t).unwrap().run().unwrap();
    assert_eq!(rep.flows_completed, 0); // both degenerate
}

// ---------------------------------------------------------------------------
// Injected hardware faults (DESIGN.md §26): fail-stops abort cleanly,
// stragglers slow things down, and faults that touch nothing change
// nothing.
// ---------------------------------------------------------------------------

#[test]
fn node_loss_mid_iteration_yields_clean_fault_report() {
    use hetsim::system::failure::{FaultClass, FaultReport, IterationFaults};
    use hetsim::util::units::Time;
    let (c, w, t) = small_setup();
    let clean = Scheduler::new(&w, &c, &t).unwrap().run().unwrap();
    assert!(clean.fault.is_none());

    // kill node 0 halfway through the clean iteration: the run must
    // terminate (not hang), report the fault, and stop the clock at it
    let half = Time(clean.iteration_time.as_ps() / 2);
    let mut sched = Scheduler::new(&w, &c, &t).unwrap();
    sched.faults = Some(IterationFaults {
        abort: Some((half, 0, FaultClass::Node)),
        slow: vec![1.0; 8],
        degraded: vec![],
    });
    let rep = sched.run().unwrap();
    assert_eq!(
        rep.fault,
        Some(FaultReport { at: half, node: 0, kind: FaultClass::Node, lost_work: half })
    );
    assert_eq!(rep.iteration_time, half);
    assert!(
        rep.events_processed < clean.events_processed,
        "aborted run processed {} events, clean run {}",
        rep.events_processed,
        clean.events_processed
    );
}

#[test]
fn straggler_strictly_increases_iteration_time() {
    use hetsim::system::failure::IterationFaults;
    let (c, w, t) = small_setup();
    let clean = Scheduler::new(&w, &c, &t).unwrap().run().unwrap();

    let mut slow = vec![1.0; 8];
    slow[0] = 2.0; // one straggling rank drags its TP group
    let mut sched = Scheduler::new(&w, &c, &t).unwrap();
    sched.faults = Some(IterationFaults { abort: None, slow, degraded: vec![] });
    let rep = sched.run().unwrap();
    assert!(rep.fault.is_none());
    assert!(
        rep.iteration_time > clean.iteration_time,
        "straggler did not slow the iteration: {} vs clean {}",
        rep.iteration_time,
        clean.iteration_time
    );
}

#[test]
fn degraded_nic_reroutes_and_severed_link_escalates() {
    use hetsim::config::cluster::FabricSpec;
    use hetsim::system::failure::{FaultClass, IterationFaults};
    use hetsim::util::units::Time;
    // 16 ranks over two hopper nodes so inter-node routes exist
    let mut m = presets::model("gpt-6.7b").unwrap();
    m.num_layers = 2;
    m.global_batch = 8;
    m.micro_batch = 4;
    let c = presets::cluster("hopper", 2).unwrap();
    let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 8, pp: 1, dp: 2 }).unwrap();
    let w = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
    let mut t = CostTable::native();
    register_costs(&w, &c, &mut t).unwrap();
    let clean = Scheduler::new(&w, &c, &t).unwrap().run().unwrap();

    // a NIC repair window on node 0: the iteration reroutes over the
    // sibling rails and completes — degraded, never aborted
    let mut sched = Scheduler::new(&w, &c, &t).unwrap();
    sched.faults = Some(IterationFaults {
        abort: None,
        slow: vec![1.0; 16],
        degraded: vec![(0, FaultClass::Nic)],
    });
    let rep = sched.run().unwrap();
    assert!(rep.fault.is_none(), "degraded run must complete, got {:?}", rep.fault);
    assert!(
        rep.iteration_time >= clean.iteration_time,
        "rerouted iteration beat the clean one: {} vs {}",
        rep.iteration_time,
        clean.iteration_time
    );

    // the same cable fault on a single-spine leaf/spine fabric leaves
    // no surviving inter-node route: the fault escalates to an
    // immediate fail-stop at the window start
    let mut c1 = c.clone();
    c1.fabric = FabricSpec::LeafSpine { spines: 1, oversubscription: 1.0 };
    let mut t1 = CostTable::native();
    register_costs(&w, &c1, &mut t1).unwrap();
    let mut sched = Scheduler::new(&w, &c1, &t1).unwrap();
    sched.faults = Some(IterationFaults {
        abort: None,
        slow: vec![1.0; 16],
        degraded: vec![(0, FaultClass::Link)],
    });
    let rep = sched.run().unwrap();
    let fault = rep.fault.expect("severed route must escalate to a fail-stop");
    assert_eq!(fault.at, Time::ZERO);
    assert_eq!(fault.node, 0);
    assert_eq!(fault.kind, FaultClass::Link);
    assert_eq!(rep.iteration_time, Time::ZERO);
}

#[test]
fn fault_on_vacant_node_is_byte_identical() {
    use hetsim::system::failure::{FaultEvent, FaultKind, FaultSpec};
    // the 8-rank workload from small_setup occupies only node 0 of a
    // two-node cluster; a straggler on node 1 touches no scheduled rank
    let (_, w, t) = small_setup();
    let c2 = presets::cluster("hopper", 2).unwrap();
    let clean = Scheduler::new(&w, &c2, &t).unwrap().run().unwrap();

    let spec = FaultSpec {
        events: vec![FaultEvent {
            at_s: 0.0,
            kind: FaultKind::Straggler { node: 1, mult: 3.0 },
        }],
        ..Default::default()
    };
    let faults = spec.resolve_iteration(&c2, 0.0);
    assert!(!faults.is_noop(), "straggler on node 1 should resolve to multipliers");
    let mut sched = Scheduler::new(&w, &c2, &t).unwrap();
    sched.faults = Some(faults);
    let rep = sched.run().unwrap();
    assert_eq!(rep.iteration_time, clean.iteration_time);
    assert_eq!(rep.events_processed, clean.events_processed);
    assert_eq!(rep.flows_completed, clean.flows_completed);
    assert_eq!(rep.compute_busy, clean.compute_busy);
    assert_eq!(rep.comm_busy, clean.comm_busy);
    assert!(rep.fault.is_none());
}

#[test]
fn fold_auto_under_faults_matches_fold_off_bit_for_bit() {
    use hetsim::simulator::SimulationBuilder;
    use hetsim::system::failure::{FaultEvent, FaultKind, FaultSpec};
    use hetsim::system::fold::FoldMode;
    let mut m = presets::model("gpt-6.7b").unwrap();
    m.num_layers = 2;
    m.global_batch = 8;
    m.micro_batch = 4;
    let c = presets::cluster("hopper", 2).unwrap();
    let par = ParallelismSpec { tp: 8, pp: 1, dp: 2 };

    // without faults this scenario folds (DP replicas are symmetric)
    let folded = SimulationBuilder::new(m.clone(), c.clone())
        .parallelism(par)
        .fold(FoldMode::Auto)
        .build()
        .unwrap();
    assert!(folded.folded(), "fault-free DP-symmetric scenario should fold");

    // a non-empty fault spec must force expansion, and the expanded
    // fold=auto run must match fold=off exactly, field for field
    let spec = FaultSpec {
        events: vec![FaultEvent {
            at_s: 0.0,
            kind: FaultKind::Straggler { node: 0, mult: 1.3 },
        }],
        ..Default::default()
    };
    let run = |mode: FoldMode| {
        let sim = SimulationBuilder::new(m.clone(), c.clone())
            .parallelism(par)
            .fold(mode)
            .faults(Some(spec.clone()))
            .build()
            .unwrap();
        assert!(!sim.folded(), "non-empty fault spec must veto folding ({mode:?})");
        sim.run_iteration().unwrap()
    };
    let auto = run(FoldMode::Auto);
    let off = run(FoldMode::Off);
    assert_eq!(auto.iteration_time, off.iteration_time);
    assert_eq!(auto.events_processed, off.events_processed);
    assert_eq!(auto.flows_completed, off.flows_completed);
    assert_eq!(auto.compute_busy, off.compute_busy);
    assert_eq!(auto.comm_busy, off.comm_busy);
    assert_eq!(auto.fault, off.fault);
}

#[test]
fn event_budget_stops_runaway_configs() {
    // a pathological but valid workload must hit the engine's event
    // budget rather than spin forever — exercised via the public API by
    // shrinking the budget through an enormous flow count would be slow;
    // instead assert the guard exists at the engine level.
    use hetsim::engine::Engine;
    use hetsim::util::units::Time;
    let mut e: Engine<u8> = Engine::new();
    e.max_events = 10;
    e.schedule_at(Time(0), 0);
    let res = e.run(|eng, _| {
        eng.schedule_in(Time(1), 0);
    });
    assert!(res.unwrap_err().to_string().contains("budget"));
}
