//! The paper's Fig-3 scenario: Llama-2 70B trained on one 4xH100 node
//! plus one 4xA100 node with *non-uniform* device groups:
//!
//! * DG0 (H100): pipeline of (TP=3, 75 layers) -> (TP=1, 5 layers),
//!   batch share 16;
//! * DG1 (A100): single stage TP=4, all 80 layers, batch share 8.
//!
//! The TP-degree mismatch (3/1 vs 4) forces gradient resharding before
//! DP synchronization (paper §3), and the example quantifies that cost
//! against a uniform deployment on the same hardware.
//!
//!     cargo run --release --example hetero_cluster

use hetsim::config::framework::ParallelismSpec;
use hetsim::simulator::SimulationBuilder;
use hetsim::system::collective::CommKind;
use hetsim::workload::partition::{fig3_cluster, fig3_model, fig3_plan};

fn main() -> anyhow::Result<()> {
    let model = fig3_model()?;
    let cluster = fig3_cluster()?;
    let plan = fig3_plan(&model, &cluster)?;

    println!("=== Fig-3 heterogeneous deployment (Llama-2 70B) ===");
    for g in &plan.groups {
        let stages: Vec<String> = g
            .stages
            .iter()
            .map(|s| format!("TP={} x {} layers", s.tp(), s.num_layers))
            .collect();
        println!("  DG{}: [{}], batch share {}", g.id, stages.join(" -> "), g.batch_share);
    }

    let sim = SimulationBuilder::new(model.clone(), cluster.clone()).framework(plan).build()?;

    // how much traffic is resharding?
    let reshard_count =
        sim.workload.collectives.iter().filter(|c| c.kind == CommKind::Reshard).count();
    let reshard_bytes: u64 = sim
        .workload
        .collectives
        .iter()
        .filter(|c| c.kind == CommKind::Reshard)
        .map(|c| c.bytes_per_rank * c.ranks.len() as u64)
        .sum();
    println!(
        "\nresharding collectives: {reshard_count} (total payload {})",
        hetsim::util::units::ByteSize(reshard_bytes)
    );

    let hetero = sim.run_iteration()?;
    println!("\nnon-uniform plan: iteration = {}", hetero.iteration_time);
    if let Some(rs) = hetero.fct_summary.get("RESHARD") {
        println!(
            "  reshard flows: {}  p50={:.1}us  max={:.1}us",
            rs.count,
            rs.p50 * 1e6,
            rs.max * 1e6
        );
    }

    // uniform comparison on the same hardware (TP=4 within each node)
    let uniform = SimulationBuilder::new(model, cluster)
        .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 2 })
        .build()?
        .run_iteration()?;
    println!("uniform TP=4 plan: iteration = {}", uniform.iteration_time);

    let ratio = hetero.iteration_time.as_secs() / uniform.iteration_time.as_secs();
    println!(
        "\nvariable-TP plan / uniform plan = {ratio:.2}x — the resharding tax the \
         paper's Table 3 attributes to variable-TP strategies. On this 8-GPU \
         example the tax outweighs the layer/batch rebalancing gain; the C1 \
         gain without resharding is isolated in `cargo bench --bench \
         ablation_partition` (uniform-TP non-uniform batch, -30%)."
    );
    Ok(())
}
