//! MoE training: Mixtral-8x7B with expert dispatch/combine all-to-alls
//! on homogeneous vs heterogeneous clusters — the workload class the
//! paper calls out for heterogeneity-aware data sharding (§3(c)).
//!
//!     cargo run --release --example moe_training

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::simulator::SimulationBuilder;
use hetsim::workload::aicb::WorkloadOptions;

fn main() -> anyhow::Result<()> {
    let model = presets::model("mixtral-8x7b")?;
    println!("=== Mixtral-8x7B ({} params) ===", model.param_count() / 1_000_000_000);

    for (label, cluster) in [
        ("hopper x4", presets::cluster("hopper", 4)?),
        ("ampere x4", presets::cluster("ampere", 4)?),
        ("hetero 2+2", presets::cluster_hetero(2, 2)?),
    ] {
        let world = cluster.total_gpus();
        let report = SimulationBuilder::new(model.clone(), cluster)
            .parallelism(ParallelismSpec { tp: 2, pp: 1, dp: world / 2 }) // paper TP=2
            .workload_options(WorkloadOptions {
                microbatch_limit: Some(1),
                ..Default::default()
            })
            .build()?
            .run_iteration()?;
        let ep = report.fct_summary.get("EP");
        println!(
            "{label:12} iteration={}  EP(a2a) flows={} p99.9={}us",
            report.iteration_time,
            ep.map(|s| s.count).unwrap_or(0),
            ep.map(|s| format!("{:.1}", s.p999 * 1e6)).unwrap_or_else(|| "-".into()),
        );
    }
    println!("\n(EP = expert-parallel all-to-all dispatch/combine traffic)");
    Ok(())
}
