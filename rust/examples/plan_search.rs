//! Plan search: rank every feasible TP×PP×DP deployment of GPT-6.7B on
//! a mixed A100+H100 cluster and compare the winner against the uniform
//! default plan — the paper's headline "plan an optimal deployment" use
//! case, driven by the parallel planner layer.
//!
//!     cargo run --release --example plan_search

use hetsim::config::presets;
use hetsim::planner::{self, PlanOptions};

fn main() -> anyhow::Result<()> {
    let model = presets::model("gpt-6.7b")?;
    let cluster = presets::cluster_hetero(1, 1)?;
    println!(
        "=== plan search: {} on {} ({} GPUs) ===\n",
        model.name,
        cluster.name,
        cluster.total_gpus()
    );

    let opts = PlanOptions { microbatch_limit: Some(2), threads: 0, refine_steps: 0, ..Default::default() };
    let report = planner::search(&model, &cluster, &opts)?;
    print!("{}", report.render(10));

    let best = report.best();
    let speedup =
        report.baseline.iteration_time.as_secs() / best.iteration_time.as_secs();
    println!(
        "\nbest plan {} is {speedup:.2}x the uniform default — the planner \
         recovers the heterogeneity-aware configuration automatically.",
        best.candidate.key()
    );
    Ok(())
}
