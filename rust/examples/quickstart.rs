//! Quickstart: simulate one training iteration of GPT-6.7B on a small
//! homogeneous H100 cluster and print the report.
//!
//!     cargo run --release --example quickstart

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::simulator::SimulationBuilder;
use hetsim::util::table::fmt_sig;
use hetsim::workload::aicb::WorkloadOptions;

fn main() -> anyhow::Result<()> {
    // Table-6 model, 4 nodes x 8 H100s.
    let model = presets::model("gpt-6.7b")?;
    let cluster = presets::cluster("hopper", 4)?;

    let report = SimulationBuilder::new(model, cluster)
        // paper TP degree; DP fills the cluster
        .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 8 })
        // one microbatch keeps the quickstart quick; drop the cap for
        // full-iteration numbers
        .workload_options(WorkloadOptions { microbatch_limit: Some(1), ..Default::default() })
        .build()?
        .run_iteration()?;

    println!("=== HetSim quickstart ===");
    println!("model:            {}", report.model_name);
    println!("cluster:          {}", report.cluster_name);
    println!("iteration time:   {}", report.iteration_time);
    println!("flows completed:  {}", report.flows_completed);
    println!("events processed: {}", report.events_processed);
    println!();
    println!("FCT summary by communication kind:");
    let mut kinds: Vec<_> = report.fct_summary.iter().collect();
    kinds.sort_by_key(|(k, _)| **k);
    for (kind, s) in kinds {
        println!(
            "  {kind:4}  flows={:<6} p50={:>10}us  p99.9={:>10}us  max={:>10}us",
            s.count,
            fmt_sig(s.p50 * 1e6),
            fmt_sig(s.p999 * 1e6),
            fmt_sig(s.max * 1e6)
        );
    }
    Ok(())
}
