//! END-TO-END DRIVER (DESIGN.md §3 "E2E driver"): the full three-layer
//! stack on a real small workload.
//!
//! Uses the **PJRT cost backend** — per-layer times come from executing
//! `artifacts/cost_model.hlo.txt` (JAX Layer-2 graph wrapping the Pallas
//! Layer-1 roofline kernel) through the `xla` crate — proving the
//! Python-AOT → Rust-PJRT → event-simulator pipeline composes.
//!
//! Scenario: a capacity planner sweeps the A100:H100 mix for a fixed
//! 4-node GPT-6.7B training cluster and reads off iteration time, tail
//! FCT and the benefit of non-uniform partitioning — the paper's
//! headline use case ("an LLM training deployer can draw inferences
//! from our simulator and plan an optimal deployment").
//!
//!     make artifacts && cargo run --release --example capacity_planning

use hetsim::config::framework::ParallelismSpec;
use hetsim::config::presets;
use hetsim::simulator::{CostBackend, SimulationBuilder};
use hetsim::util::table::{fmt_sig, Table};
use hetsim::workload::aicb::WorkloadOptions;

fn main() -> anyhow::Result<()> {
    let nodes = 4u32;
    let mut model = presets::model("gpt-6.7b")?;
    // full-iteration batch scaled to the 4-node testbed (3 microbatches
    // per DP replica) so non-uniform batch shares are visible end to end
    model.global_batch = 192;
    println!("=== capacity planning sweep: GPT-6.7B on {nodes} nodes (PJRT cost backend) ===\n");

    let mut t = Table::new(
        "A100:H100 mix sweep (one full iteration, global batch 192)",
        &["ampere nodes", "hopper nodes", "partitioning", "iteration", "p99.9 FCT (us)", "flows"],
    );

    for ampere in 0..=nodes {
        let hopper = nodes - ampere;
        let cluster = match (ampere, hopper) {
            (0, h) => presets::cluster("hopper", h)?,
            (a, 0) => presets::cluster("ampere", a)?,
            (a, h) => presets::cluster_hetero(a, h)?,
        };
        let world = cluster.total_gpus();
        for hetero_part in [false, true] {
            // uniform-only on homogeneous clusters (identical result)
            if hetero_part && (ampere == 0 || hopper == 0) {
                continue;
            }
            let report = SimulationBuilder::new(model.clone(), cluster.clone())
                .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: world / 4 })
                .cost_backend(CostBackend::Pjrt)
                .hetero_partitioning(hetero_part)
                .workload_options(WorkloadOptions::default())
                .build()?
                .run_iteration()?;
            let mut all = report.fct_all;
            t.row(vec![
                ampere.to_string(),
                hopper.to_string(),
                if hetero_part { "non-uniform" } else { "uniform" }.into(),
                report.iteration_time.human(),
                fmt_sig(all.percentile(99.9) * 1e6),
                report.flows_completed.to_string(),
            ]);
        }
    }
    print!("{}", t.markdown());
    let dir = hetsim::report::results_dir();
    let path = t.write_csv(&dir, "capacity_planning")?;
    println!("\ncsv: {}", path.display());
    println!("\nReading the table: pure-Hopper is fastest; mixes degrade");
    println!("super-linearly under uniform partitioning, and non-uniform");
    println!("partitioning recovers part of the gap — the paper's core claim.");
    Ok(())
}
