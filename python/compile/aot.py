"""AOT lowering: JAX cost graphs -> HLO text artifacts for the Rust side.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import collective, roofline


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_cost_model() -> str:
    args = model.example_args_cost()
    return to_hlo_text(jax.jit(model.cost_fn).lower(*args))


def lower_coll_model() -> str:
    args = model.example_args_coll()
    return to_hlo_text(jax.jit(model.coll_fn).lower(*args))


def self_check() -> None:
    """Sanity-execute the jitted graphs before writing artifacts."""
    rows = [
        model.make_layer_row(kind=2, hidden=4096, ffn=16384, seq=2048, mbs=8),
        model.make_layer_row(kind=1, hidden=4096, heads=32, seq=2048, mbs=8),
    ]
    layers = model.pad_rows(rows, model.ROWS, model.LAYER_FIELDS)
    gpus = jnp.tile(model.gpu_row("H100"), (model.ROWS, 1))
    t = jax.jit(model.cost_fn)(layers, gpus)
    assert float(t[0]) > 0.0 and float(t[1]) > 0.0, "cost_fn returned zeros"
    coll = jnp.zeros((model.COLL_ROWS, collective.COLL_FIELDS), jnp.float32)
    coll = coll.at[0].set(jnp.asarray([0.0, 8, 1e9, 25e9, 1e-6, 0, 0, 0]))
    tc = jax.jit(model.coll_fn)(coll)
    assert float(tc[0]) > 0.0, "coll_fn returned zero"


def manifest() -> dict:
    """Shape/layout contract consumed by rust/src/compute/mod.rs."""
    return {
        "cost_model": {
            "file": "cost_model.hlo.txt",
            "rows": model.ROWS,
            "layer_fields": model.LAYER_FIELDS,
            "gpu_fields": roofline.GPU_FIELDS,
        },
        "coll_model": {
            "file": "coll_model.hlo.txt",
            "rows": model.COLL_ROWS,
            "coll_fields": collective.COLL_FIELDS,
        },
        "dtype_bytes": model.DTYPE_BYTES,
        "bwd_flops_factor": model.BWD_FLOPS_FACTOR,
        "bwd_bytes_factor": model.BWD_BYTES_FACTOR,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-check", action="store_true")
    ns = ap.parse_args()

    if not ns.skip_check:
        self_check()

    os.makedirs(ns.out_dir, exist_ok=True)
    for name, text in [
        ("cost_model.hlo.txt", lower_cost_model()),
        ("coll_model.hlo.txt", lower_coll_model()),
    ]:
        path = os.path.join(ns.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")
    mpath = os.path.join(ns.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(), f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
