"""Layer-2 JAX cost graph for HetSim.

For a *simulator* paper the analogue of the model forward/backward is the
per-layer compute-cost estimator: the function that maps transformer /
MoE layer hyperparameters and a GPU descriptor to an execution-time
estimate. This module builds that graph in JAX — FLOPs/bytes formulas in
``jnp`` feeding the Layer-1 Pallas roofline kernel — so the entire cost
table is one fused XLA computation, AOT-lowered by :mod:`compile.aot`.

Layer-descriptor row (LAYER_FIELDS=10), must match
``rust/src/compute/mod.rs``:

    0 kind        0=embedding 1=attention 2=mlp 3=moe 4=other
    1 hidden      model hidden size
    2 ffn         FFN hidden size (per expert for MoE)
    3 heads       attention heads
    4 seq         sequence length
    5 mbs         microbatch size
    6 n_experts   MoE expert count (0 for dense)
    7 topk        MoE router top-k (0 for dense)
    8 tp          tensor-parallel degree the layer is sharded over
    9 is_bwd      0=forward 1=backward

GPU-descriptor row: see kernels/roofline.py (GPU_FIELDS=8).

The same formulas are mirrored exactly in ``rust/src/compute/cost.rs``;
``rust/tests/integration_runtime.rs`` cross-checks the PJRT artifact
against the Rust mirror.
"""

import jax
import jax.numpy as jnp

from .kernels import collective, roofline

LAYER_FIELDS = 10
DTYPE_BYTES = 2.0  # bf16 weights/activations
BWD_FLOPS_FACTOR = 2.0  # dgrad + wgrad ~= 2x forward FLOPs
BWD_BYTES_FACTOR = 2.0

ROWS = roofline.ROWS
COLL_ROWS = collective.ROWS

# ---------------------------------------------------------------------------
# GPU presets (Table 5 of the paper + datasheet peak numbers).
#
# The eff_* factors calibrate the roofline to the paper's measured Fig-5
# ratios (see DESIGN.md §4 Substitutions):
#   * MLP is dense-GEMM compute-bound: equal eff_mlp makes the A100/H100
#     time ratio the raw FLOPs ratio 989/312 = 3.17x (paper: 3-4x).
#   * Attention GEMMs are smaller and under-utilize H100's larger MXU:
#     eff_attn(H100) < eff_attn(A100) lands the ratio at ~1.9x (paper:
#     "up to 1.9x").
#   * Embedding gather is random-access bound; A100 achieves a tiny
#     fraction of HBM bandwidth, H100 ~1/3 (async copy engines) — this
#     calibrates to the paper's measured 36.1x.
# ---------------------------------------------------------------------------
GPU_PRESETS = {
    #            peak_flops  mem_bw    eff_mlp eff_attn eff_embed eff_mem  overhead
    "A100": (312.0e12, 1555.0e9, 0.55, 0.50, 0.0200, 0.75, 4.5e-6, 0.0),
    "H100": (989.0e12, 3350.0e9, 0.55, 0.305, 0.3352, 0.78, 4.5e-6, 0.0),
}


def gpu_row(name):
    return jnp.asarray(GPU_PRESETS[name], jnp.float32)


# ---------------------------------------------------------------------------
# FLOPs / bytes formulas (vectorized over descriptor rows)
# ---------------------------------------------------------------------------


def layer_flops_bytes(layers):
    """f32[rows, LAYER_FIELDS] -> (flops f32[rows], bytes f32[rows]).

    All quantities are per TP shard: dense work divides by ``tp``
    (Megatron-style column/row-parallel sharding; embeddings are
    vocab-parallel).
    """
    layers = jnp.asarray(layers, jnp.float32)
    kind = layers[:, 0]
    hidden = layers[:, 1]
    ffn = layers[:, 2]
    heads = layers[:, 3]
    seq = layers[:, 4]
    mbs = layers[:, 5]
    n_experts = layers[:, 6]
    topk = layers[:, 7]
    tp = jnp.maximum(layers[:, 8], 1.0)
    is_bwd = layers[:, 9]

    tokens = mbs * seq
    d = DTYPE_BYTES

    # --- embedding (gather + write); FLOPs negligible, memory bound.
    emb_flops = 2.0 * tokens * hidden
    emb_bytes = tokens * (2.0 * hidden * d + 4.0)  # row read + out write + idx

    # --- attention: QKVO projections + scores + context.
    attn_flops = mbs * (8.0 * seq * hidden * hidden + 4.0 * seq * seq * hidden)
    attn_bytes = (
        mbs * (12.0 * seq * hidden * d + heads * seq * seq * d)
        + 4.0 * hidden * hidden * d  # QKVO weights
    )

    # --- dense MLP: two GEMMs (h->ffn, ffn->h).
    mlp_flops = 4.0 * tokens * hidden * ffn
    mlp_bytes = tokens * (hidden + ffn) * 2.0 * d + 2.0 * hidden * ffn * d

    # --- MoE: router + top-k expert MLPs; all resident expert weights
    # stream from HBM once per microbatch (tokens scatter across experts).
    moe_flops = 2.0 * tokens * hidden * n_experts + topk * mlp_flops
    moe_bytes = (
        tokens * (hidden + topk * ffn) * 2.0 * d
        + n_experts * 2.0 * hidden * ffn * d
    )

    # --- other (layernorm/residual/rotary): vector work.
    other_flops = 10.0 * tokens * hidden
    other_bytes = 6.0 * tokens * hidden * d

    flops = jnp.select(
        [kind == 0.0, kind == 1.0, kind == 2.0, kind == 3.0],
        [emb_flops, attn_flops, mlp_flops, moe_flops],
        other_flops,
    )
    nbytes = jnp.select(
        [kind == 0.0, kind == 1.0, kind == 2.0, kind == 3.0],
        [emb_bytes, attn_bytes, mlp_bytes, moe_bytes],
        other_bytes,
    )

    flops = flops / tp
    nbytes = nbytes / tp
    bwd_f = jnp.where(is_bwd > 0.5, BWD_FLOPS_FACTOR, 1.0)
    bwd_b = jnp.where(is_bwd > 0.5, BWD_BYTES_FACTOR, 1.0)
    return flops * bwd_f, nbytes * bwd_b


def cost_fn(layers, gpus):
    """The AOT entry point for artifacts/cost_model.hlo.txt.

    layers: f32[ROWS, LAYER_FIELDS], gpus: f32[ROWS, GPU_FIELDS]
    -> f32[ROWS] seconds. Zero-padded rows yield the launch overhead of
    their GPU row; Rust ignores rows beyond the live count.
    """
    flops, nbytes = layer_flops_bytes(layers)
    kind = jnp.asarray(layers, jnp.float32)[:, 0]
    work = jnp.stack([flops, nbytes, kind, jnp.zeros_like(kind)], axis=1)
    return roofline.roofline_times(work, gpus)


def coll_fn(coll):
    """AOT entry point for artifacts/coll_model.hlo.txt.

    coll: f32[COLL_ROWS, COLL_FIELDS] -> f32[COLL_ROWS] seconds.
    """
    return collective.collective_times(coll)


def example_args_cost():
    z = jnp.zeros((ROWS, LAYER_FIELDS), jnp.float32)
    g = jnp.zeros((ROWS, roofline.GPU_FIELDS), jnp.float32)
    return z, g


def example_args_coll():
    return (jnp.zeros((COLL_ROWS, collective.COLL_FIELDS), jnp.float32),)


# ---------------------------------------------------------------------------
# Convenience: build descriptor rows for named layers (used by tests and
# by aot.py's self-check; Rust builds its own rows natively).
# ---------------------------------------------------------------------------


def make_layer_row(
    kind, hidden, ffn=0, heads=0, seq=2048, mbs=1, n_experts=0, topk=0, tp=1, is_bwd=0
):
    return jnp.asarray(
        [kind, hidden, ffn, heads, seq, mbs, n_experts, topk, tp, is_bwd],
        jnp.float32,
    )


def pad_rows(rows, total, fields):
    """Stack a list of f32[fields] rows and zero-pad to [total, fields]."""
    n = len(rows)
    assert n <= total, (n, total)
    base = jnp.zeros((total, fields), jnp.float32)
    if n == 0:
        return base
    return base.at[:n].set(jnp.stack(rows))
