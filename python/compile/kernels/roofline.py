"""Batched roofline cost kernel (Pallas, Layer 1).

Given a table of work descriptors (``flops``, ``bytes``, ``kind``) and a
row-aligned table of GPU descriptors, computes per-row execution time

    t = max(flops / (peak_flops * eff_flops(kind)),
            bytes / (mem_bw   * eff_mem(kind)))   + launch_overhead

This is the compute hot-spot of the simulator's build path: one PJRT
execution fills the whole (layer-kind x model x GPU-type x microbatch)
cost table that the Rust event simulator consumes.

Hardware adaptation (paper -> TPU idiom): the paper profiles CUDA kernels
on A100/H100; we re-express the *cost model* as a blocked elementwise
Pallas kernel. Rows are tiled ``(BLOCK, FIELDS)`` into VMEM via
``BlockSpec``; the select/divide/max pipeline vectorizes on the VPU. The
kernel is HBM-bandwidth bound, so BLOCK is chosen to keep the VMEM
footprint small (BLOCK * 17 * 4 B = ~17 KiB at BLOCK=256) while
amortizing the HBM->VMEM transfer.

Field layouts (must match ``rust/src/compute/mod.rs``):

work row  (WORK_FIELDS=4):  flops, bytes, kind, _pad
gpu row   (GPU_FIELDS=8):   peak_flops, mem_bw, eff_mlp, eff_attn,
                            eff_embed, eff_mem, overhead_s, _pad

kind codes: 0=embedding 1=attention 2=mlp 3=moe 4=other
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORK_FIELDS = 4
GPU_FIELDS = 8
ROWS = 256
DEFAULT_BLOCK = 64

KIND_EMBEDDING = 0.0
KIND_ATTENTION = 1.0
KIND_MLP = 2.0
KIND_MOE = 3.0
KIND_OTHER = 4.0


def _roofline_block(work_ref, gpu_ref, out_ref):
    """Kernel body: one (BLOCK, FIELDS) tile -> (BLOCK,) times."""
    flops = work_ref[:, 0]
    nbytes = work_ref[:, 1]
    kind = work_ref[:, 2]

    peak = gpu_ref[:, 0]
    bw = gpu_ref[:, 1]
    eff_mlp = gpu_ref[:, 2]
    eff_attn = gpu_ref[:, 3]
    eff_embed = gpu_ref[:, 4]
    eff_mem = gpu_ref[:, 5]
    overhead = gpu_ref[:, 6]

    is_embed = kind == KIND_EMBEDDING
    is_attn = kind == KIND_ATTENTION
    # mlp and moe GEMMs share the dense-GEMM efficiency; "other"
    # (layernorm/residual) is vector work, modelled with eff_attn.
    eff_f = jnp.where(is_attn | (kind == KIND_OTHER), eff_attn, eff_mlp)
    eff_m = jnp.where(is_embed, eff_embed, eff_mem)

    t_compute = flops / (peak * eff_f)
    t_memory = nbytes / (bw * eff_m)
    out_ref[:] = jnp.maximum(t_compute, t_memory) + overhead


@functools.partial(jax.jit, static_argnames=("block",))
def roofline_times(work, gpu, block=DEFAULT_BLOCK):
    """Evaluate the roofline kernel over a full descriptor table.

    work: f32[rows, WORK_FIELDS], gpu: f32[rows, GPU_FIELDS] -> f32[rows].
    ``rows`` must be a multiple of ``block``.
    """
    rows = work.shape[0]
    assert rows % block == 0, (rows, block)
    assert work.shape[1] == WORK_FIELDS and gpu.shape[1] == GPU_FIELDS
    grid = (rows // block,)
    return pl.pallas_call(
        _roofline_block,
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, WORK_FIELDS), lambda i: (i, 0)),
            pl.BlockSpec((block, GPU_FIELDS), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(work.astype(jnp.float32), gpu.astype(jnp.float32))
