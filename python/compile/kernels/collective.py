"""Batched alpha-beta collective cost kernel (Pallas, Layer 1).

Computes the analytic completion time of a collective operation over a
device group, per NCCL-style algorithm structure. Used by the
Sailor-like analytical baseline in Rust (the event-driven path derives
transfer times from the flow-level network simulation instead).

coll row (COLL_FIELDS=8):
    algo, nranks, size_bytes, bottleneck_bw (B/s), per_hop_latency_s,
    n_extra_hops, _pad, _pad

algo codes (must match rust/src/baselines/analytical.rs):
    0 = allreduce (ring)   t = 2(n-1)/n * S/bw + 2(n-1) * lat
    1 = allgather          t =  (n-1)/n * S/bw +  (n-1) * lat
    2 = reducescatter      t =  (n-1)/n * S/bw +  (n-1) * lat
    3 = alltoall           t =  (n-1)/n * S/bw +  (n-1) * lat
    4 = broadcast          t =  S/bw + ceil(log2 n) * lat
    5 = p2p                t =  S/bw + lat

``n_extra_hops * lat`` is added for routes that traverse extra fixed-
delay hops (e.g. the two PCIe trips to reach the NIC, per paper §5).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COLL_FIELDS = 8
ROWS = 512
DEFAULT_BLOCK = 64

ALGO_ALLREDUCE = 0.0
ALGO_ALLGATHER = 1.0
ALGO_REDUCESCATTER = 2.0
ALGO_ALLTOALL = 3.0
ALGO_BROADCAST = 4.0
ALGO_P2P = 5.0


def _collective_block(coll_ref, out_ref):
    algo = coll_ref[:, 0]
    n = jnp.maximum(coll_ref[:, 1], 1.0)
    size = coll_ref[:, 2]
    bw = jnp.maximum(coll_ref[:, 3], 1.0)
    lat = coll_ref[:, 4]
    extra_hops = coll_ref[:, 5]

    steps_ring = n - 1.0
    frac = steps_ring / n  # (n-1)/n
    log2n = jnp.ceil(jnp.log2(jnp.maximum(n, 1.0)))

    t_allreduce = 2.0 * frac * size / bw + 2.0 * steps_ring * lat
    t_onepass = frac * size / bw + steps_ring * lat
    t_broadcast = size / bw + log2n * lat
    t_p2p = size / bw + lat

    t = jnp.where(
        algo == ALGO_ALLREDUCE,
        t_allreduce,
        jnp.where(
            algo == ALGO_BROADCAST,
            t_broadcast,
            jnp.where(algo == ALGO_P2P, t_p2p, t_onepass),
        ),
    )
    out_ref[:] = t + extra_hops * lat


@functools.partial(jax.jit, static_argnames=("block",))
def collective_times(coll, block=DEFAULT_BLOCK):
    """coll: f32[rows, COLL_FIELDS] -> f32[rows] seconds."""
    rows = coll.shape[0]
    assert rows % block == 0, (rows, block)
    assert coll.shape[1] == COLL_FIELDS
    return pl.pallas_call(
        _collective_block,
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        grid=(rows // block,),
        in_specs=[pl.BlockSpec((block, COLL_FIELDS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(coll.astype(jnp.float32))
