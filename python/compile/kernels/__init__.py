"""Layer-1 Pallas kernels for the HetSim cost model.

Two kernels, both lowered with ``interpret=True`` (the CPU PJRT client
cannot execute Mosaic custom-calls; see DESIGN.md §1):

* :mod:`.roofline` — batched per-(layer, GPU) roofline time estimate.
* :mod:`.collective` — batched alpha-beta collective-cost estimate.

``ref.py`` holds the pure-``jnp`` oracles used by pytest.
"""

from . import collective, ref, roofline  # noqa: F401

__all__ = ["roofline", "collective", "ref"]
