"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

pytest asserts ``allclose(kernel, ref)`` — this is the core correctness
signal for the build path. Keep these in lockstep with roofline.py /
collective.py (and with the Rust mirror in rust/src/compute/cost.rs).
"""

import jax.numpy as jnp

from .collective import ALGO_ALLREDUCE, ALGO_BROADCAST, ALGO_P2P
from .roofline import KIND_ATTENTION, KIND_EMBEDDING, KIND_OTHER


def roofline_times_ref(work, gpu):
    """Oracle for roofline.roofline_times."""
    work = jnp.asarray(work, jnp.float32)
    gpu = jnp.asarray(gpu, jnp.float32)
    flops, nbytes, kind = work[:, 0], work[:, 1], work[:, 2]
    peak, bw = gpu[:, 0], gpu[:, 1]
    eff_mlp, eff_attn = gpu[:, 2], gpu[:, 3]
    eff_embed, eff_mem = gpu[:, 4], gpu[:, 5]
    overhead = gpu[:, 6]

    eff_f = jnp.where(
        (kind == KIND_ATTENTION) | (kind == KIND_OTHER), eff_attn, eff_mlp
    )
    eff_m = jnp.where(kind == KIND_EMBEDDING, eff_embed, eff_mem)
    t_compute = flops / (peak * eff_f)
    t_memory = nbytes / (bw * eff_m)
    return jnp.maximum(t_compute, t_memory) + overhead


def collective_times_ref(coll):
    """Oracle for collective.collective_times."""
    coll = jnp.asarray(coll, jnp.float32)
    algo = coll[:, 0]
    n = jnp.maximum(coll[:, 1], 1.0)
    size = coll[:, 2]
    bw = jnp.maximum(coll[:, 3], 1.0)
    lat = coll[:, 4]
    extra_hops = coll[:, 5]

    steps = n - 1.0
    frac = steps / n
    log2n = jnp.ceil(jnp.log2(jnp.maximum(n, 1.0)))

    t_allreduce = 2.0 * frac * size / bw + 2.0 * steps * lat
    t_onepass = frac * size / bw + steps * lat
    t_broadcast = size / bw + log2n * lat
    t_p2p = size / bw + lat

    t = jnp.where(
        algo == ALGO_ALLREDUCE,
        t_allreduce,
        jnp.where(
            algo == ALGO_BROADCAST,
            t_broadcast,
            jnp.where(algo == ALGO_P2P, t_p2p, t_onepass),
        ),
    )
    return t + extra_hops * lat
