"""HetSim build-time Python package (Layers 1 and 2).

This package exists only on the *compile path*: ``make artifacts`` runs
:mod:`compile.aot` once to lower the JAX cost graphs (which call the
Pallas kernels) to HLO text under ``artifacts/``; the Rust simulator
loads those via PJRT and Python is never on the simulation path.
"""
