"""AOT lowering: artifacts are valid HLO text with the expected interface."""

import os

import pytest

from compile import aot, model
from compile.kernels import collective, roofline


class TestLowering:
    def test_cost_model_hlo_text(self):
        text = aot.lower_cost_model()
        assert "HloModule" in text
        assert f"f32[{model.ROWS},{model.LAYER_FIELDS}]" in text
        assert f"f32[{model.ROWS},{roofline.GPU_FIELDS}]" in text

    def test_coll_model_hlo_text(self):
        text = aot.lower_coll_model()
        assert "HloModule" in text
        assert f"f32[{model.COLL_ROWS},{collective.COLL_FIELDS}]" in text

    def test_self_check_passes(self):
        aot.self_check()

    def test_manifest_contract(self):
        m = aot.manifest()
        assert m["cost_model"]["rows"] == model.ROWS == 256
        assert m["cost_model"]["layer_fields"] == model.LAYER_FIELDS == 10
        assert m["cost_model"]["gpu_fields"] == roofline.GPU_FIELDS == 8
        assert m["coll_model"]["rows"] == model.COLL_ROWS == 512
        assert m["coll_model"]["coll_fields"] == collective.COLL_FIELDS == 8

    def test_main_writes_artifacts(self, tmp_path, monkeypatch):
        import sys

        monkeypatch.setattr(
            sys, "argv", ["aot", "--out-dir", str(tmp_path), "--skip-check"]
        )
        aot.main()
        for f in ("cost_model.hlo.txt", "coll_model.hlo.txt", "manifest.json"):
            assert os.path.exists(tmp_path / f), f
        assert (tmp_path / "cost_model.hlo.txt").read_text().startswith("HloModule")
