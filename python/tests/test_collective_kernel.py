"""Pallas collective kernel vs pure-jnp oracle + semantic checks."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import collective, ref


def _rand_coll(rng, rows):
    c = np.zeros((rows, collective.COLL_FIELDS), np.float32)
    c[:, 0] = rng.integers(0, 6, rows).astype(np.float32)  # algo
    c[:, 1] = rng.integers(1, 1025, rows).astype(np.float32)  # nranks
    c[:, 2] = rng.uniform(1.0, 1e10, rows)  # size
    c[:, 3] = rng.uniform(1e9, 1e12, rows)  # bw
    c[:, 4] = rng.uniform(0.0, 1e-5, rows)  # latency
    c[:, 5] = rng.integers(0, 5, rows).astype(np.float32)  # extra hops
    return c


class TestCollectiveVsRef:
    @pytest.mark.parametrize("block", [16, 64, 128, 256])
    def test_matches_ref(self, block):
        rng = np.random.default_rng(3)
        c = _rand_coll(rng, 512)
        got = collective.collective_times(jnp.asarray(c), block=block)
        want = ref.collective_times_ref(c)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_value_sweep(self, seed):
        rng = np.random.default_rng(seed)
        c = _rand_coll(rng, 64)
        got = collective.collective_times(jnp.asarray(c), block=32)
        want = ref.collective_times_ref(c)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


class TestCollectiveSemantics:
    def _one(self, algo, n, size, bw, lat=0.0, hops=0.0):
        c = np.zeros((64, collective.COLL_FIELDS), np.float32)
        c[0] = [algo, n, size, bw, lat, hops, 0, 0]
        return float(collective.collective_times(jnp.asarray(c), block=32)[0])

    def test_allreduce_is_twice_allgather_bytes(self):
        ar = self._one(collective.ALGO_ALLREDUCE, 8, 1e9, 25e9)
        ag = self._one(collective.ALGO_ALLGATHER, 8, 1e9, 25e9)
        assert abs(ar - 2 * ag) / ar < 1e-5

    def test_single_rank_transfers_nothing(self):
        ar = self._one(collective.ALGO_ALLREDUCE, 1, 1e9, 25e9, lat=1e-6)
        assert ar < 1e-9

    def test_p2p_is_serialization_plus_latency(self):
        t = self._one(collective.ALGO_P2P, 2, 1e9, 1e10, lat=5e-6)
        assert abs(t - (0.1 + 5e-6)) / t < 1e-5

    def test_extra_hops_add_latency(self):
        base = self._one(collective.ALGO_P2P, 2, 1e9, 1e10, lat=5e-6)
        hop = self._one(collective.ALGO_P2P, 2, 1e9, 1e10, lat=5e-6, hops=2)
        # f32 arithmetic: allow a few ULPs around the 0.1 s base value
        assert abs((hop - base) - 2 * 5e-6) < 5e-9

    def test_time_scales_with_size(self):
        t1 = self._one(collective.ALGO_ALLREDUCE, 8, 1e9, 25e9)
        t2 = self._one(collective.ALGO_ALLREDUCE, 8, 2e9, 25e9)
        assert abs(t2 - 2 * t1) / t2 < 1e-4
