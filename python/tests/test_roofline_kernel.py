"""Pallas roofline kernel vs pure-jnp oracle (the L1 correctness signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, roofline

jax.config.update("jax_enable_x64", False)


def _rand_tables(rng, rows):
    work = np.zeros((rows, roofline.WORK_FIELDS), np.float32)
    work[:, 0] = rng.uniform(0.0, 1e15, rows)  # flops
    work[:, 1] = rng.uniform(1.0, 1e12, rows)  # bytes
    work[:, 2] = rng.integers(0, 5, rows).astype(np.float32)  # kind
    gpu = np.zeros((rows, roofline.GPU_FIELDS), np.float32)
    gpu[:, 0] = rng.uniform(1e12, 2e15, rows)  # peak flops
    gpu[:, 1] = rng.uniform(1e11, 4e12, rows)  # mem bw
    gpu[:, 2:6] = rng.uniform(0.01, 1.0, (rows, 4))  # efficiencies
    gpu[:, 6] = rng.uniform(0.0, 1e-5, rows)  # overhead
    return work, gpu


class TestRooflineVsRef:
    @pytest.mark.parametrize("block", [16, 32, 64, 128, 256])
    def test_matches_ref_across_block_sizes(self, block):
        rng = np.random.default_rng(7)
        work, gpu = _rand_tables(rng, 256)
        got = roofline.roofline_times(jnp.asarray(work), jnp.asarray(gpu), block=block)
        want = ref.roofline_times_ref(work, gpu)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    @pytest.mark.parametrize("rows", [64, 128, 256, 512])
    def test_matches_ref_across_row_counts(self, rows):
        rng = np.random.default_rng(rows)
        work, gpu = _rand_tables(rng, rows)
        got = roofline.roofline_times(jnp.asarray(work), jnp.asarray(gpu), block=64)
        want = ref.roofline_times_ref(work, gpu)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_value_sweep(self, seed):
        rng = np.random.default_rng(seed)
        work, gpu = _rand_tables(rng, 64)
        got = roofline.roofline_times(jnp.asarray(work), jnp.asarray(gpu), block=32)
        want = ref.roofline_times_ref(work, gpu)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_zero_rows_yield_overhead_only(self):
        work = np.zeros((64, roofline.WORK_FIELDS), np.float32)
        gpu = np.zeros((64, roofline.GPU_FIELDS), np.float32)
        gpu[:, 0] = 1e12
        gpu[:, 1] = 1e11
        gpu[:, 2:6] = 0.5
        gpu[:, 6] = 3e-6
        got = np.asarray(roofline.roofline_times(jnp.asarray(work), jnp.asarray(gpu), block=32))
        np.testing.assert_allclose(got, 3e-6, rtol=1e-6)


class TestRooflineSemantics:
    def _one(self, flops, nbytes, kind, gpu_vals):
        work = np.zeros((64, roofline.WORK_FIELDS), np.float32)
        work[0] = [flops, nbytes, kind, 0]
        gpu = np.tile(np.asarray(gpu_vals, np.float32), (64, 1))
        return float(
            roofline.roofline_times(jnp.asarray(work), jnp.asarray(gpu), block=32)[0]
        )

    GPU = (1e12, 1e11, 0.5, 0.5, 0.1, 0.8, 0.0, 0.0)

    def test_compute_bound_region(self):
        # flops term dominates: t = flops / (peak * eff_mlp)
        t = self._one(1e12, 1.0, roofline.KIND_MLP, self.GPU)
        assert abs(t - 1e12 / (1e12 * 0.5)) / t < 1e-5

    def test_memory_bound_region(self):
        t = self._one(1.0, 1e11, roofline.KIND_MLP, self.GPU)
        assert abs(t - 1e11 / (1e11 * 0.8)) / t < 1e-5

    def test_embedding_uses_embed_efficiency(self):
        t = self._one(0.0, 1e10, roofline.KIND_EMBEDDING, self.GPU)
        assert abs(t - 1e10 / (1e11 * 0.1)) / t < 1e-5

    def test_attention_uses_attn_efficiency(self):
        gpu = (1e12, 1e11, 0.9, 0.3, 0.1, 0.8, 0.0, 0.0)
        t = self._one(1e12, 1.0, roofline.KIND_ATTENTION, gpu)
        assert abs(t - 1e12 / (1e12 * 0.3)) / t < 1e-5

    def test_monotone_in_flops(self):
        t1 = self._one(1e12, 1e9, roofline.KIND_MLP, self.GPU)
        t2 = self._one(2e12, 1e9, roofline.KIND_MLP, self.GPU)
        assert t2 >= t1
