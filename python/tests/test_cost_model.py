"""L2 cost-graph semantics + calibration against the paper's Fig-5 ratios."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import roofline


def _times(rows, gpu_name):
    layers = model.pad_rows(rows, model.ROWS, model.LAYER_FIELDS)
    gpus = jnp.tile(model.gpu_row(gpu_name), (model.ROWS, 1))
    return np.asarray(jax.jit(model.cost_fn)(layers, gpus))[: len(rows)]


GPT67 = dict(hidden=4096, ffn=16384, heads=32, seq=2048, mbs=8)
GPT13 = dict(hidden=5120, ffn=20480, heads=40, seq=2048, mbs=8)
MIXTRAL = dict(hidden=4096, ffn=14336, heads=32, seq=2048, mbs=4)


def _model_rows(hp, moe=False, tp=1, is_bwd=0):
    rows = [
        model.make_layer_row(0, hp["hidden"], seq=hp["seq"], mbs=hp["mbs"], tp=tp, is_bwd=is_bwd),
        model.make_layer_row(
            1, hp["hidden"], heads=hp["heads"], seq=hp["seq"], mbs=hp["mbs"], tp=tp, is_bwd=is_bwd
        ),
    ]
    if moe:
        rows.append(
            model.make_layer_row(
                3, hp["hidden"], ffn=hp["ffn"], seq=hp["seq"], mbs=hp["mbs"],
                n_experts=8, topk=2, tp=tp, is_bwd=is_bwd,
            )
        )
    else:
        rows.append(
            model.make_layer_row(
                2, hp["hidden"], ffn=hp["ffn"], seq=hp["seq"], mbs=hp["mbs"], tp=tp, is_bwd=is_bwd
            )
        )
    return rows


class TestCalibration:
    """The paper's measured Fig-5 degradation ratios (DESIGN.md §3)."""

    @pytest.mark.parametrize("hp,moe", [(GPT67, False), (GPT13, False), (MIXTRAL, True)])
    def test_mlp_degradation_3x_to_4x(self, hp, moe):
        a = _times(_model_rows(hp, moe), "A100")
        h = _times(_model_rows(hp, moe), "H100")
        ratio = a[2] / h[2]
        assert 3.0 <= ratio <= 4.0, ratio

    @pytest.mark.parametrize("hp,moe", [(GPT67, False), (GPT13, False), (MIXTRAL, True)])
    def test_attention_degradation_at_most_1_9x(self, hp, moe):
        a = _times(_model_rows(hp, moe), "A100")
        h = _times(_model_rows(hp, moe), "H100")
        ratio = a[1] / h[1]
        assert 1.5 <= ratio <= 1.95, ratio

    def test_embedding_degradation_about_36x(self):
        a = _times(_model_rows(GPT67), "A100")
        h = _times(_model_rows(GPT67), "H100")
        ratio = a[0] / h[0]
        assert 30.0 <= ratio <= 40.0, ratio

    def test_embedding_absolute_time_is_small(self):
        # Paper: embedding is a poor optimization target — one pass/iter
        # and small absolute time vs MLP.
        h = _times(_model_rows(GPT67), "H100")
        assert h[0] < h[2]


class TestCostSemantics:
    def test_tp_sharding_divides_time(self):
        t1 = _times(_model_rows(GPT67, tp=1), "H100")
        t8 = _times(_model_rows(GPT67, tp=8), "H100")
        # compute-bound MLP: near-linear scaling (overhead-limited floor)
        assert t8[2] < t1[2] / 4.0

    def test_backward_costs_about_twice_forward(self):
        f = _times(_model_rows(GPT67, is_bwd=0), "H100")
        b = _times(_model_rows(GPT67, is_bwd=1), "H100")
        for i in range(3):
            assert 1.5 <= b[i] / f[i] <= 2.5

    def test_moe_costs_more_than_dense_same_ffn(self):
        dense = model.make_layer_row(2, 4096, ffn=14336, seq=2048, mbs=4)
        moe = model.make_layer_row(3, 4096, ffn=14336, seq=2048, mbs=4, n_experts=8, topk=2)
        t = _times([dense, moe], "H100")
        assert t[1] > t[0]

    def test_flops_bytes_nonnegative(self):
        layers = model.pad_rows(_model_rows(GPT67), model.ROWS, model.LAYER_FIELDS)
        flops, nbytes = model.layer_flops_bytes(layers)
        assert float(jnp.min(flops)) >= 0.0
        assert float(jnp.min(nbytes)) >= 0.0

    def test_h100_strictly_faster_everywhere(self):
        for moe, hp in [(False, GPT67), (False, GPT13), (True, MIXTRAL)]:
            a = _times(_model_rows(hp, moe), "A100")
            h = _times(_model_rows(hp, moe), "H100")
            assert (h < a).all()

    def test_bigger_model_costs_more(self):
        t67 = _times(_model_rows(GPT67), "H100")
        t13 = _times(_model_rows(GPT13), "H100")
        assert t13[1] > t67[1] and t13[2] > t67[2]
